"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs the
pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.kv_gather import kv_gather


def _pallas_unavailable_reason():
    """Capability probe: run one trivial kernel in interpret mode.  The
    kernels target the Pallas-TPU API surface (e.g. ``pltpu.CompilerParams``),
    which older / CPU-only jax builds do not ship — the guard keys on the
    actual failure, not on a version string."""
    try:
        pool = jnp.zeros((2, 1, 4), jnp.float32)
        kv_gather(pool, jnp.array([0], jnp.int32), interpret=True)
        return None
    except Exception as e:  # pragma: no cover - environment dependent
        return f"{type(e).__name__}: {e}"


_REASON = _pallas_unavailable_reason()
pytestmark = pytest.mark.skipif(
    _REASON is not None,
    reason=f"Pallas-TPU kernel API unavailable on this jax build: {_REASON}")

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,KV,S,dh,bq,bk", [
        (1, 4, 4, 128, 64, 64, 64),     # MHA
        (2, 4, 2, 128, 32, 32, 64),     # GQA, rectangular blocks
        (1, 8, 1, 256, 64, 128, 128),   # MQA
        (2, 6, 2, 64, 16, 16, 16),      # odd-ish head count
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, B, H, KV, S, dh, bq, bk, causal):
        kq, kk, kv_ = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (B, H, S, dh), jnp.float32)
        k = jax.random.normal(kk, (B, KV, S, dh), jnp.float32)
        v = jax.random.normal(kv_, (B, KV, S, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                              interpret=True)
        want = ref.ref_flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, want, **_tol(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q = jax.random.normal(KEY, (1, 2, 64, 32), dtype)
        k = jax.random.normal(KEY, (1, 2, 64, 32), dtype)
        v = jax.random.normal(KEY, (1, 2, 64, 32), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                              interpret=True)
        want = ref.ref_flash_attention(q.astype(jnp.float32),
                                       k.astype(jnp.float32),
                                       v.astype(jnp.float32), causal=True)
        assert out.dtype == dtype
        np.testing.assert_allclose(out.astype(jnp.float32), want, **_tol(dtype))

    @given(st.sampled_from([32, 64, 128]), st.sampled_from([1, 2, 4]),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_property_block_size_invariance(self, bk, group, seed):
        """The tiling must never change the math."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        H, S, dh = 2 * group, 128, 32
        q = jax.random.normal(k1, (1, H, S, dh), jnp.float32)
        k = jax.random.normal(k2, (1, 2, S, dh), jnp.float32)
        v = jax.random.normal(k3, (1, 2, S, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=32, block_k=bk,
                              interpret=True)
        want = ref.ref_flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,H,KV,S,dh,bs", [
        (2, 4, 4, 256, 64, 64),
        (2, 8, 2, 256, 32, 128),
        (1, 4, 1, 512, 64, 256),
    ])
    def test_matches_ref(self, B, H, KV, S, dh, bs):
        kq, kk, kv_, kl = jax.random.split(KEY, 4)
        q = jax.random.normal(kq, (B, H, dh), jnp.float32)
        kc = jax.random.normal(kk, (B, S, KV, dh), jnp.float32)
        vc = jax.random.normal(kv_, (B, S, KV, dh), jnp.float32)
        lengths = jax.random.randint(kl, (B,), 1, S + 1)
        out = decode_attention(q, kc, vc, lengths, block_s=bs, interpret=True)
        want = ref.ref_decode_attention(q, kc, vc, lengths)
        np.testing.assert_allclose(out, want, rtol=3e-5, atol=3e-5)

    def test_short_lengths_ignore_tail(self):
        """Bytes past `lengths` must not affect the result."""
        kq, kk, kv_ = jax.random.split(KEY, 3)
        B, H, KV, S, dh = 1, 2, 2, 128, 16
        q = jax.random.normal(kq, (B, H, dh), jnp.float32)
        kc = jax.random.normal(kk, (B, S, KV, dh), jnp.float32)
        vc = jax.random.normal(kv_, (B, S, KV, dh), jnp.float32)
        lengths = jnp.array([40])
        out1 = decode_attention(q, kc, vc, lengths, block_s=32, interpret=True)
        kc2 = kc.at[:, 40:].set(999.0)
        vc2 = vc.at[:, 40:].set(-999.0)
        out2 = decode_attention(q, kc2, vc2, lengths, block_s=32, interpret=True)
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q = jax.random.normal(KEY, (2, 4, 32), dtype)
        kc = jax.random.normal(KEY, (2, 128, 2, 32), dtype)
        vc = jax.random.normal(KEY, (2, 128, 2, 32), dtype)
        lengths = jnp.array([100, 128])
        out = decode_attention(q, kc, vc, lengths, block_s=64, interpret=True)
        want = ref.ref_decode_attention(q.astype(jnp.float32),
                                        kc.astype(jnp.float32),
                                        vc.astype(jnp.float32), lengths)
        np.testing.assert_allclose(out.astype(jnp.float32), want, **_tol(dtype))


class TestKVGather:
    @pytest.mark.parametrize("P,G,W,N", [(16, 8, 32, 5), (64, 16, 128, 64),
                                         (8, 4, 8, 1)])
    def test_matches_ref(self, P, G, W, N):
        pool = jax.random.normal(KEY, (P, G, W), jnp.float32)
        idx = jax.random.randint(KEY, (N,), 0, P)
        out = kv_gather(pool, idx, interpret=True)
        np.testing.assert_allclose(out, ref.ref_kv_gather(pool, idx))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_dtypes(self, dtype):
        pool = jnp.arange(16 * 8 * 16).reshape(16, 8, 16).astype(dtype)
        idx = jnp.array([3, 3, 0, 15], jnp.int32)
        out = kv_gather(pool, idx, interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(ref.ref_kv_gather(pool, idx)))

    @given(st.integers(0, 2**31 - 1), st.integers(1, 12))
    @settings(max_examples=10, deadline=None)
    def test_property_any_index_pattern(self, seed, n):
        key = jax.random.PRNGKey(seed)
        pool = jax.random.normal(key, (10, 4, 8), jnp.float32)
        idx = jax.random.randint(key, (n,), 0, 10)
        out = kv_gather(pool, idx, interpret=True)
        np.testing.assert_allclose(out, ref.ref_kv_gather(pool, idx))
