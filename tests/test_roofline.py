"""Roofline machinery tests: HLO collective parsing, the scan-body-once
pitfall, and term arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import (HW, collective_bytes_from_hlo,
                                     model_flops, roofline_terms)


class TestCollectiveParsing:
    def test_counts_all_reduce_result_bytes(self):
        hlo = """
  %all-reduce.48 = f32[128,16]{1,0} all-reduce(%wrapped), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), use_global_device_ids=true, to_apply=%region
"""
        assert collective_bytes_from_hlo(hlo) == 128 * 16 * 4

    def test_skips_done_ops(self):
        hlo = """
  %all-gather-start = (bf16[8,64]{1,0}, bf16[128,64]{1,0}) all-gather-start(%p), replica_groups=[1,16]<=[16]
  %all-gather-done = bf16[128,64]{1,0} all-gather-done(%all-gather-start)
"""
        # counts the start's largest buffer (the gathered result), not -done
        assert collective_bytes_from_hlo(hlo) == 128 * 64 * 2

    def test_reduce_scatter_scaled_by_group(self):
        hlo = """
  %reduce-scatter.1 = f32[8,16]{1,0} reduce-scatter(%x), replica_groups=[2,8]<=[16], dimensions={0}
"""
        assert collective_bytes_from_hlo(hlo) == 8 * 16 * 4 * 8

    def test_ignores_instruction_names(self):
        hlo = "  %all-reduce.5 = f32[4]{0} add(%a, %b)\n"
        assert collective_bytes_from_hlo(hlo) == 0

    def test_real_module_nonzero(self):
        mesh = jax.make_mesh((1,), ("x",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        with mesh:
            c = jax.jit(lambda x: jax.lax.with_sharding_constraint(
                x.sum(), NamedSharding(mesh, P()))).lower(
                jnp.ones((8, 8))).compile()
        # single-device: no collectives expected
        assert collective_bytes_from_hlo(c.as_text()) == 0.0


def _cost_analysis_is_mapping():
    """Newer jax returns one dict from ``Compiled.cost_analysis()``; older
    builds return a per-device list, which this test's indexing (and the
    roofline pass it documents) does not support."""
    try:
        ca = jax.jit(lambda x: x + 1.0).lower(1.0).compile().cost_analysis()
        return hasattr(ca, "keys")
    except Exception:  # pragma: no cover - environment dependent
        return False


@pytest.mark.skipif(not _cost_analysis_is_mapping(),
                    reason="Compiled.cost_analysis() is not a dict on this "
                           "jax build (old per-device list API)")
class TestScanBodyOnce:
    def test_cost_analysis_counts_scan_body_once(self):
        """The measurement pitfall that forces the unrolled roofline pass:
        XLA cost_analysis of a lax.scan counts the body ONCE."""
        M = 64
        a = jnp.ones((M, M))
        w = jnp.ones((10, M, M))

        def scanned(a, w):
            return jax.lax.scan(lambda x, wi: (x @ wi, None), a, w)[0]

        def unrolled(a, w):
            return jax.lax.scan(lambda x, wi: (x @ wi, None), a, w,
                                unroll=True)[0]

        f_scan = jax.jit(scanned).lower(a, w).compile().cost_analysis()["flops"]
        f_unroll = jax.jit(unrolled).lower(a, w).compile().cost_analysis()["flops"]
        assert f_unroll == pytest.approx(10 * f_scan, rel=0.01)


class TestTerms:
    def test_bottleneck_selection(self):
        cfg = get_config("qwen3-14b")
        shape = SHAPES["train_4k"]
        r = roofline_terms(cfg, shape, flops_per_dev=1e15, bytes_per_dev=1e9,
                           collective_bytes_per_dev=1e9, n_dev=256)
        assert r["bottleneck"] == "compute"
        r2 = roofline_terms(cfg, shape, flops_per_dev=1e12, bytes_per_dev=1e13,
                            collective_bytes_per_dev=1e9, n_dev=256)
        assert r2["bottleneck"] == "memory"

    def test_model_flops_train_vs_prefill(self):
        cfg = get_config("qwen3-0.6b")
        t = model_flops(cfg, SHAPES["train_4k"])
        p = model_flops(cfg, SHAPES["prefill_32k"])
        # same token count (4096*256 == 32768*32); train is 3x forward
        assert t == pytest.approx(3 * p)

    def test_moe_uses_active_params(self):
        cfg = get_config("qwen3-moe-30b-a3b")
        f = model_flops(cfg, SHAPES["train_4k"])
        n_active = cfg.active_param_count()
        assert f == pytest.approx(6 * n_active * 4096 * 256)
        assert n_active < 0.25 * cfg.param_count()

    def test_perf_fraction_bounded_by_useful_ratio(self):
        cfg = get_config("qwen3-14b")
        shape = SHAPES["train_4k"]
        mf = model_flops(cfg, shape)
        # if HLO flops == model flops and compute-bound, fraction == 1
        r = roofline_terms(cfg, shape, flops_per_dev=mf / 256,
                           bytes_per_dev=1.0, collective_bytes_per_dev=1.0,
                           n_dev=256)
        assert r["perf_fraction"] == pytest.approx(1.0)
        assert r["useful_flops_ratio"] == pytest.approx(1.0)
