"""Live SLO observability (DESIGN.md §Observability, online half): mergeable
quantile sketches with their relative rank-error bound, streaming windowed
metrics and their fleet merge algebra, per-tenant SLO burn-rate monitors,
critical-path extraction + what-if projection, Perfetto flow events, the
perf-trajectory regression gate, and the zero-perturbation contract with
monitors attached to the golden cluster/fleet runs."""
import json
import math
import os
import random
import threading

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cluster import ClusterSim, load_trace
from repro.cluster.metrics import RequestRecord, percentile
from repro.core.scheduler import Policy
from repro.core.simulator import PAPER_MARGIN_BPS
from repro.fleet import make_router
from repro.fleet.sim import CacheConfig, FleetSim
from repro.obs import (Ewma, MetricsRegistry, MultiMonitor, QuantileSketch,
                       SLOMonitor, SLOTarget, StreamMonitor, Tracer,
                       WindowedSeries, aggregate_profile, compare,
                       extract_all, extract_critical_path, format_profile,
                       labeled, metric_direction, parse_derived,
                       project_request, project_wire_scale, rows_from_csv,
                       to_chrome_trace, validate_bench_result,
                       validate_chrome_trace, window_index)
from repro.obs import regress

DATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")
GBPS = 1e9 / 8


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


# ---------------------------------------------------------------------------
# Quantile sketch: the documented relative-error bound + merge algebra
# ---------------------------------------------------------------------------
class TestQuantileSketch:
    @settings(max_examples=8)
    @given(st.integers(0, 10 ** 6))
    def test_rel_err_bound_vs_exact_percentiles_10k(self, seed):
        """The headline guarantee on >= 10k-sample runs:
        |q_est - q_true| <= rel_err * q_true at every quantile, where
        q_true is the exact nearest-rank order statistic."""
        rng = random.Random(seed)
        n = 10_000
        samples = [rng.lognormvariate(0.0, 2.0) for _ in range(n)]
        sk = QuantileSketch(rel_err=0.01)
        for v in samples:
            sk.add(v)
        assert sk.count == n
        for q in (0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 0.999, 1.0):
            exact = percentile(samples, q)
            est = sk.quantile(q)
            assert abs(est - exact) <= sk.rel_err * exact + 1e-12, \
                (q, est, exact)

    @settings(max_examples=20)
    @given(st.integers(0, 10 ** 6), st.integers(2, 5))
    def test_merge_associative_commutative(self, seed, parts):
        """Bucket-count addition: any permutation / parenthesisation of the
        same sketch set merges to the identical sketch (node-order
        invariance for fleet rollups)."""
        rng = random.Random(seed)
        shards = [QuantileSketch(rel_err=0.02) for _ in range(parts)]
        for _ in range(300):
            shards[rng.randrange(parts)].add(rng.lognormvariate(0.0, 1.5))
        forward = QuantileSketch.merged(shards)
        backward = QuantileSketch.merged(list(reversed(shards)))
        shuffled = list(shards)
        rng.shuffle(shuffled)
        # left-fold with arbitrary grouping: ((s0 + s1) + s2) ...
        nested = QuantileSketch(0.02)
        for s in shuffled:
            nested.merge(s)
        assert forward == backward == nested
        for q in (0.5, 0.95, 0.99):
            assert forward.quantile(q) == backward.quantile(q) \
                == nested.quantile(q)
        # inputs untouched by the static merge
        assert sum(s.count for s in shards) == forward.count

    def test_single_value_is_exact_via_minmax_clamp(self):
        sk = QuantileSketch()
        sk.add(3.7)
        for q in (0.0, 0.5, 1.0):
            assert sk.quantile(q) == 3.7

    def test_zero_and_negative_values_land_in_zero_bucket(self):
        sk = QuantileSketch()
        sk.add(0.0)
        sk.add(-1e-3)  # negative noise clamps, never raises on log()
        sk.add(5.0)
        assert sk.count == 3
        assert sk.quantile(0.5) == 0.0  # rank 2 of 3 is still in the zeros
        assert sk.quantile(1.0) == 5.0

    def test_deterministic_no_reservoir(self):
        a, b = QuantileSketch(), QuantileSketch()
        for i in range(5000):
            v = (i * 37 % 101) + 0.5
            a.add(v)
            b.add(v)
        assert a == b and a.quantile(0.99) == b.quantile(0.99)

    def test_serialisation_roundtrip_preserves_equality(self):
        sk = QuantileSketch(rel_err=0.05)
        for v in (0.0, 1e-3, 1.0, 42.0, 1e6):
            sk.add(v)
        back = QuantileSketch.from_dict(json.loads(json.dumps(sk.to_dict())))
        assert back == sk
        assert back.quantile(0.95) == sk.quantile(0.95)
        assert back.sum == sk.sum and back.min == sk.min

    def test_incompatible_parameters_refuse_to_merge(self):
        with pytest.raises(ValueError):
            QuantileSketch(0.01).merge(QuantileSketch(0.02))

    def test_empty_and_domain_errors(self):
        sk = QuantileSketch()
        assert math.isnan(sk.quantile(0.5))
        assert math.isnan(sk.min) and math.isnan(sk.mean)
        assert sk.snapshot()["count"] == 0
        with pytest.raises(ValueError):
            sk.quantile(1.5)
        with pytest.raises(ValueError):
            QuantileSketch(rel_err=0.0)


# ---------------------------------------------------------------------------
# Histogram warm-up bias fix (the satellite's failing-before regression test)
# ---------------------------------------------------------------------------
class TestHistogramWarmupBias:
    def test_late_samples_move_p99(self):
        """The old keep-first-N reservoir froze percentiles at the run's
        first ``max_samples`` observations — a latency shift after warm-up
        was invisible.  The sketch-backed histogram must see it."""
        reg = MetricsRegistry()
        h = reg.histogram("ttft", max_samples=64)
        for _ in range(64):
            h.observe(1.0)
        assert h.percentile(0.99) == 1.0  # exact while the buffer holds all
        for _ in range(64):
            h.observe(100.0)  # the regression the old reservoir dropped
        p99 = h.percentile(0.99)
        exact = percentile([1.0] * 64 + [100.0] * 64, 0.99)  # = 100.0
        assert p99 > 50.0
        assert abs(p99 - exact) <= 0.01 * exact

    def test_exact_until_buffer_overflows_then_sketch(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", max_samples=10)
        xs = [float(i) for i in range(10)]
        for x in xs:
            h.observe(x)
        assert h.percentile(0.5) == percentile(xs, 0.5)  # exact at capacity
        h.observe(10.0)
        xs.append(10.0)
        est = h.percentile(0.5)
        exact = percentile(xs, 0.5)
        assert abs(est - exact) <= 0.01 * exact + 1e-12  # sketch bound now

    def test_sketch_copy_is_consistent_and_mergeable(self):
        reg = MetricsRegistry()
        a = reg.histogram("a")
        b = reg.histogram("b")
        for i in range(100):
            a.observe(float(i + 1))
            b.observe(float(1000 + i))
        merged = a.sketch().merge(b.sketch())
        assert merged.count == 200
        # the copy is detached: merging did not mutate a's own sketch
        assert a.snapshot()["count"] == 100
        assert merged.quantile(1.0) == 1099.0


# ---------------------------------------------------------------------------
# Windowing: alignment, sliding views, EWMA — virtual times only
# ---------------------------------------------------------------------------
class TestWindowing:
    def test_boundary_opens_the_new_window(self):
        assert window_index(0.0, 1.0) == 0
        assert window_index(0.999999, 1.0) == 0
        assert window_index(1.0, 1.0) == 1  # [k*w, (k+1)*w) semantics
        assert window_index(2.0 - 1e-13, 1.0) == 2  # epsilon absorbs noise
        assert window_index(7.25, 0.5) == 14

    @settings(max_examples=100)
    @given(st.floats(0.0, 1e6), st.floats(1e-3, 1e3))
    def test_window_contains_its_observation(self, t, width):
        k = window_index(t, width)
        assert k * width <= t + 1e-6 * max(1.0, t)
        assert t < (k + 1) * width + 1e-6 * max(1.0, t)

    def test_series_windows_counts_and_quantile_line(self):
        s = WindowedSeries(width_s=1.0)
        for t, v in ((0.2, 1.0), (0.8, 3.0), (1.5, 10.0), (3.0, 7.0)):
            s.observe(t, v)
        ws = s.windows()
        assert [w.index for w in ws] == [0, 1, 3]
        assert [w.count for w in ws] == [2, 1, 1]
        assert ws[0].start_s == 0.0 and ws[0].end_s == 1.0
        assert s.window_at(1.7).index == 1 and s.window_at(2.5) is None
        line = s.series(q=1.0)
        assert [(t0, c) for t0, _, c in line] == [(0.0, 2), (1.0, 1), (3.0, 1)]
        assert line[1][1] == 10.0  # max of window 1
        assert s.total().count == 4

    def test_sliding_last_k_merges_tumbling_subwindows(self):
        s = WindowedSeries(width_s=1.0)
        for t in (0.5, 1.5, 2.5):
            s.observe(t, t)
        assert s.last(1).count == 1  # newest window only
        assert s.last(2).count == 2
        assert s.last(10).count == 3
        at1 = s.last(2, before=1.9)  # windows 0 and 1
        assert at1.count == 2 and at1.max == 1.5
        assert s.last(1, before=99.0).count == 0  # empty span -> empty sketch

    def test_max_windows_drops_oldest(self):
        s = WindowedSeries(width_s=1.0, max_windows=2)
        for t in (0.5, 1.5, 2.5):
            s.observe(t, 1.0)
        assert [w.index for w in s.windows()] == [1, 2]
        assert len(s) == 2

    def test_merge_equals_union_of_observations(self):
        obs = [(0.1, 2.0), (0.9, 4.0), (1.2, 8.0), (2.7, 1.0)]
        a, b, union = (WindowedSeries(1.0) for _ in range(3))
        for i, (t, v) in enumerate(obs):
            (a if i % 2 else b).observe(t, v)
            union.observe(t, v)
        a.merge(b)
        assert [w.index for w in a.windows()] \
            == [w.index for w in union.windows()]
        for wa, wu in zip(a.windows(), union.windows()):
            assert wa.sketch == wu.sketch
        with pytest.raises(ValueError):
            a.merge(WindowedSeries(2.0))

    def test_ewma_half_life_decay(self):
        e = Ewma(half_life_s=2.0)
        assert math.isnan(e.value)
        assert e.update(0.0, 10.0) == 10.0  # first sample seeds
        # one half-life later: weights split 50/50
        assert e.update(2.0, 0.0) == pytest.approx(5.0)
        # zero dt: full-decay weight 1.0 on the old value's share
        assert e.update(2.0, 5.0) == pytest.approx(5.0)
        with pytest.raises(ValueError):
            Ewma(0.0)

    def test_width_must_be_positive(self):
        with pytest.raises(ValueError):
            WindowedSeries(0.0)


# ---------------------------------------------------------------------------
# StreamMonitor: per-request vocabulary, tenants, fleet merge algebra
# ---------------------------------------------------------------------------
def _record(req_id="r0", tenant="", ttft=2.0, queue=0.5, ctx=1000,
            hot=250):
    return RequestRecord(req_id, ctx, 0.5, arrival_s=1.0,
                         admit_s=1.0 + queue, flow_done_s=2.5,
                         prefill_done_s=1.0 + ttft, layer_compute_s=0.0,
                         num_layers=0, bytes_total=7e6, tenant=tenant,
                         hot_tokens=hot)


class TestStreamMonitor:
    def test_record_request_emits_vocabulary_and_tenant_labels(self):
        m = StreamMonitor(width_s=1.0)
        m.record_request(3.0, _record(tenant="acme"))
        m.record_request(3.5, _record(req_id="r1"))  # tenantless
        names = dict.fromkeys(n for n, _ in m.names())
        assert set(names) == set(StreamMonitor.REQUEST_METRICS)
        assert m.tenants("ttft_s") == ["acme"]
        assert m.series("ttft_s").total().count == 2  # unlabelled sees both
        assert m.series("ttft_s", tenant="acme").total().count == 1
        assert m.series("hot_token_rate").total().max == pytest.approx(0.25)
        assert m.series("wire_bytes").total().max == 7e6
        with pytest.raises(KeyError):
            m.series("ttft_s", tenant="nope")

    def test_undone_record_nan_metrics_are_skipped(self):
        m = StreamMonitor()
        m.record_request(1.0, RequestRecord("r", 100, 0.0, arrival_s=0.0))
        assert all(n != "ttft_s" for n, _ in m.names())

    def test_inc_counts_unit_events_per_window(self):
        m = StreamMonitor(width_s=1.0)
        m.inc("pool.reallocs", 0.5)
        m.inc("pool.reallocs", 0.6, n=3)
        m.inc("pool.reallocs", 1.5)
        wins = m.series("pool.reallocs").windows()
        assert [(w.index, w.count) for w in wins] == [(0, 4), (1, 1)]

    def test_fleet_merge_is_node_order_invariant(self):
        nodes = [StreamMonitor(width_s=1.0) for _ in range(3)]
        rng = random.Random(11)
        for i, m in enumerate(nodes):
            for j in range(20):
                m.record_request(rng.uniform(0, 5),
                                 _record(req_id=f"n{i}r{j}",
                                         tenant=("t0", "t1", "")[j % 3],
                                         ttft=rng.uniform(0.1, 3.0)))
        fwd = StreamMonitor.merged(nodes)
        rev = StreamMonitor.merged(list(reversed(nodes)))
        assert fwd.snapshot() == rev.snapshot()
        assert fwd.series("ttft_s").total().count == 60
        # inputs untouched
        assert nodes[0].series("ttft_s").total().count == 20

    def test_spawn_copies_config_not_data(self):
        m = StreamMonitor(width_s=0.5, rel_err=0.02, max_windows=7,
                          ewma_half_life_s=3.0)
        m.observe("x", 1.0, 1.0)
        child = m.spawn()
        assert (child.width_s, child.rel_err, child.max_windows,
                child.ewma_half_life_s) == (0.5, 0.02, 7, 3.0)
        assert child.names() == []

    def test_ewma_rides_along_when_configured(self):
        m = StreamMonitor(ewma_half_life_s=1.0)
        m.observe("ttft_s", 0.0, 4.0)
        m.observe("ttft_s", 1.0, 0.0)
        assert m.ewma("ttft_s") == pytest.approx(2.0)
        assert math.isnan(m.ewma("nope"))
        assert math.isnan(StreamMonitor().ewma("ttft_s"))

    def test_multimonitor_fans_out_to_stream_and_slo(self):
        stream = StreamMonitor(width_s=1.0)
        slo = SLOMonitor([SLOTarget(ttft_s=1.0)], width_s=1.0)
        multi = MultiMonitor([stream, slo])
        multi.record_request(0.5, _record(ttft=5.0))  # bad for the SLO
        multi.inc("n", 0.5)
        assert stream.series("ttft_s").total().count == 1
        assert slo.status()[""]["bad"] == 1
        child = multi.spawn()
        assert isinstance(child.monitors[0], StreamMonitor)
        assert isinstance(child.monitors[1], SLOMonitor)


# ---------------------------------------------------------------------------
# SLO burn rates and breach instants
# ---------------------------------------------------------------------------
class TestSLO:
    def test_target_validation_and_is_good(self):
        with pytest.raises(ValueError):
            SLOTarget(goal=1.0, ttft_s=1.0)
        with pytest.raises(ValueError):
            SLOTarget()  # needs at least one threshold
        tgt = SLOTarget(ttft_s=1.0, added_ttft_s=0.2)
        assert tgt.is_good(0.9, 0.1)
        assert not tgt.is_good(1.1, 0.1)  # ttft ceiling
        assert not tgt.is_good(0.9, 0.3)  # added-ttft budget

    def test_burn_rate_is_bad_fraction_over_budget(self):
        slo = SLOMonitor([SLOTarget(ttft_s=1.0, goal=0.9)], width_s=1.0,
                         short_windows=1, long_windows=2)
        for i in range(8):
            slo.record(0.1 * i, ttft_s=0.5)
        for i in range(2):
            slo.record(0.8 + 0.05 * i, ttft_s=5.0)
        short, long = slo.burn_rates("", 0.9)
        # 2 bad of 10 in the window: bad_fraction 0.2 over budget 0.1
        assert short == pytest.approx(2.0)
        assert long == pytest.approx(2.0)  # only one window populated

    def test_breach_needs_both_windows_over_threshold(self):
        tr = Tracer(FakeClock())
        slo = SLOMonitor([SLOTarget(ttft_s=1.0, goal=0.5)], width_s=1.0,
                         short_windows=1, long_windows=4, tracer=tr)
        # 3 windows of good traffic fill the long window's budget headroom
        for k in range(3):
            for i in range(10):
                slo.record(k + 0.1 * i, ttft_s=0.1)
        # one bad burst: short window burns hot, long window still healthy
        for i in range(10):
            slo.record(3.0 + 0.05 * i, ttft_s=9.0)
        assert not slo.breached()  # two-window AND suppressed the blip
        # sustained badness drags the long window over too
        t = 4.0
        while not slo.breached():
            slo.record(t, ttft_s=9.0)
            t += 0.05
        breaches = tr.instants(SLOMonitor.TRACK, "slo_breach")
        assert len(breaches) == 1
        args = breaches[0].args
        assert args["burn_short"] > 1.0 and args["burn_long"] > 1.0
        assert args["goal"] == 0.5
        # recovery emits the paired instant exactly once
        while slo.breached():
            slo.record(t, ttft_s=0.1)
            t += 0.05
        assert len(tr.instants(SLOMonitor.TRACK, "slo_recover")) == 1
        assert slo.status()[""]["breaches"] == 1

    def test_tenant_routing_and_default_fallback(self):
        slo = SLOMonitor([SLOTarget(ttft_s=1.0),
                          SLOTarget(tenant="gold", ttft_s=0.1)],
                         width_s=1.0)
        slo.record(0.5, tenant="gold", ttft_s=0.5)   # bad for gold's 0.1
        slo.record(0.5, tenant="other", ttft_s=0.5)  # good for default 1.0
        st = slo.status(0.5)
        assert st["gold"]["bad"] == 1
        assert st[""]["bad"] == 0 and st[""]["total"] == 1
        assert st["gold"]["burn_short"] > 1.0
        assert slo.tenants() == ["", "gold"]

    def test_no_matching_target_is_ignored(self):
        slo = SLOMonitor([SLOTarget(tenant="gold", ttft_s=1.0)])
        slo.record(0.5, tenant="stranger", ttft_s=99.0)
        assert slo.status()["gold"]["total"] == 0

    def test_duplicate_targets_and_bad_windows_raise(self):
        with pytest.raises(ValueError):
            SLOMonitor([SLOTarget(ttft_s=1.0), SLOTarget(ttft_s=2.0)])
        with pytest.raises(ValueError):
            SLOMonitor([SLOTarget(ttft_s=1.0)], short_windows=3,
                       long_windows=2)

    def test_record_request_uses_queue_plus_stall_as_added(self):
        slo = SLOMonitor([SLOTarget(added_ttft_s=0.1, goal=0.9)],
                         width_s=1.0)
        slo.record_request(2.0, _record(queue=0.5))  # queue 0.5 > 0.1 budget
        assert slo.status()[""]["bad"] == 1

    def test_spawn_is_fresh_with_same_targets(self):
        slo = SLOMonitor([SLOTarget(ttft_s=1.0)], width_s=2.0,
                         burn_threshold=3.0)
        slo.record(0.0, ttft_s=9.0)
        child = slo.spawn()
        assert child.status()[""]["total"] == 0
        assert child.width_s == 2.0 and child.burn_threshold == 3.0


# ---------------------------------------------------------------------------
# Critical path: tiling, tie-breaks, gates, what-if projection
# ---------------------------------------------------------------------------
def _summary(tr, track, req_id, arrival, done, **extra):
    tr.instant(track, "request", t=done, cat="cluster", req_id=req_id,
               arrival_s=arrival, prefill_done_s=done, **extra)


class TestCriticalPathUnits:
    def test_segments_tile_the_ttft_exactly(self):
        tr = Tracer(FakeClock())
        tr.span_at("r0", "queue", 0.0, 1.0)
        tr.span_at("r0", "wire", 1.0, 3.0, layer=0)
        tr.span_at("r0", "compute", 3.0, 4.0, layer=0)
        _summary(tr, "r0", "r0", 0.0, 4.0)
        p = extract_critical_path(tr, "r0")
        assert [s.name for s in p.segments] == ["queue", "wire", "compute"]
        assert p.segments[0].t0 == p.arrival_s
        assert p.segments[-1].t1 == p.prefill_done_s
        for a, b in zip(p.segments, p.segments[1:]):
            assert a.t1 == b.t0  # gap-free
        assert p.ttft_s == 4.0
        assert p.by_category() == {"queue": 1.0, "wire": 2.0, "compute": 1.0}
        assert p.segments[1].layer == 0

    def test_unspanned_interval_becomes_a_gate(self):
        tr = Tracer(FakeClock())
        tr.span_at("r0", "queue", 0.0, 1.0)
        # nothing covers (1.0, 1.5): the assembly/startup gate
        tr.span_at("r0", "wire", 1.5, 2.0)
        _summary(tr, "r0", "r0", 0.0, 2.0)
        p = extract_critical_path(tr, "r0")
        assert [s.name for s in p.segments] == ["queue", "gate", "wire"]
        gate = p.segments[1]
        assert (gate.t0, gate.t1) == (1.0, 1.5)

    def test_stall_never_wins_a_tie(self):
        tr = Tracer(FakeClock())
        tr.span_at("r0", "stall", 0.0, 2.0)
        tr.span_at("r0", "wire", 0.5, 2.0)  # ends at the same instant
        _summary(tr, "r0", "r0", 0.0, 2.0)
        p = extract_critical_path(tr, "r0")
        assert p.segments[-1].name == "wire"
        # but a stall with no competitor still carries the path
        tr2 = Tracer(FakeClock())
        tr2.span_at("r1", "stall", 0.0, 1.0)
        _summary(tr2, "r1", "r1", 0.0, 1.0)
        assert extract_critical_path(tr2, "r1").segments[0].name == "stall"

    def test_compute_beats_wire_at_the_frontier(self):
        tr = Tracer(FakeClock())
        tr.span_at("r0", "wire", 0.0, 1.0)
        tr.span_at("r0", "compute", 0.5, 1.0)
        _summary(tr, "r0", "r0", 0.0, 1.0)
        p = extract_critical_path(tr, "r0")
        assert p.segments[-1].name == "compute"

    def test_missing_summary_raises(self):
        with pytest.raises(ValueError):
            extract_critical_path(Tracer(FakeClock()), "nope")

    def test_aggregate_profile_shares_sum_to_one(self):
        tr = Tracer(FakeClock())
        for i, dur in enumerate((1.0, 3.0)):
            trk = f"r{i}"
            tr.span_at(trk, "wire", 0.0, dur)
            _summary(tr, trk, trk, 0.0, dur)
        prof = aggregate_profile(extract_all(tr))
        assert prof["requests"] == 2
        assert prof["total_s"] == pytest.approx(4.0)
        assert prof["by_category"]["wire"]["share"] == pytest.approx(1.0)
        out = format_profile(prof)
        assert "wire" in out and "2 requests" in out

    def test_wire_scale_must_be_positive(self):
        tr = Tracer(FakeClock())
        with pytest.raises(ValueError):
            project_request(tr, "r0", 0.0)


class TestCriticalPathGolden:
    """Extraction + projection over a real traced cluster run."""

    @pytest.fixture(scope="class")
    def traced(self):
        tr = Tracer()
        trace = load_trace(os.path.join(DATA, "golden_trace.json"))
        sim = ClusterSim(cap_bps=50 * GBPS, policy=Policy.CAL_STALL_OPT,
                         margin_bps=PAPER_MARGIN_BPS, tracer=tr)
        return tr, sim.run(trace)

    def test_every_request_path_tiles_arrival_to_first_token(self, traced):
        tr, res = traced
        paths = extract_all(tr)
        assert len(paths) == sum(1 for r in res.records if r.done) > 0
        for p in paths:
            assert p.segments, p.req_id
            assert p.segments[0].t0 == pytest.approx(p.arrival_s, abs=1e-9)
            assert p.segments[-1].t1 == pytest.approx(p.prefill_done_s,
                                                      abs=1e-9)
            for a, b in zip(p.segments, p.segments[1:]):
                assert a.t1 == pytest.approx(b.t0, abs=1e-9)
                assert a.dur_s > 0
            assert sum(s.dur_s for s in p.segments) \
                == pytest.approx(p.ttft_s, abs=1e-6)

    def test_projection_at_scale_one_reproduces_measured_ttft(self, traced):
        tr, res = traced
        out = project_wire_scale(tr, 1.0)
        assert out["requests"] > 0
        for p in out["projections"]:
            assert p.projected_ttft_s == pytest.approx(p.measured_ttft_s,
                                                       abs=1e-9), p.req_id
        assert out["p95_added_ttft_cut_s"] == pytest.approx(0.0, abs=1e-9)

    def test_faster_wire_never_hurts(self, traced):
        tr, _ = traced
        out = project_wire_scale(tr, 2.0)
        for p in out["projections"]:
            assert p.projected_ttft_s <= p.measured_ttft_s + 1e-9, p.req_id
        assert out["p95_added_ttft_cut_s"] >= -1e-9
        assert out["projected_ttft_p95_s"] <= out["measured_ttft_p95_s"] + 1e-9


# ---------------------------------------------------------------------------
# Perfetto flow events: pool realloc -> reshaped wire span arrows
# ---------------------------------------------------------------------------
class TestFlowEvents:
    def _doc(self, flow_in="pool:0", flow_ids=("pool:0",)):
        tr = Tracer(FakeClock())
        tr.instant("pool", "realloc", t=1.0, cat="pool",
                   flow_ids={f"r{i}": fid for i, fid in enumerate(flow_ids)})
        # the reshaped span STARTS before the realloc (it was in flight)
        tr.span_at("r0", "wire", 0.5, 2.0, cat="wire", flow_in=flow_in)
        return to_chrome_trace(tr)

    def test_matched_pair_exports_s_then_f_at_span_end(self):
        doc = self._doc()
        assert validate_chrome_trace(doc) == []
        starts = [e for e in doc["traceEvents"] if e["ph"] == "s"]
        finishes = [e for e in doc["traceEvents"] if e["ph"] == "f"]
        assert len(starts) == len(finishes) == 1
        assert starts[0]["id"] == finishes[0]["id"] == "pool:0"
        assert starts[0]["ts"] == 1.0e6  # at the realloc instant
        # bound at the span END so the arrow runs forward in time even
        # though the reshaped span started before the realloc
        assert finishes[0]["ts"] == 2.0e6
        assert finishes[0]["bp"] == "e"

    def test_unmatched_ids_add_no_dangling_arrows(self):
        # produced but never consumed
        doc = self._doc(flow_in=None, flow_ids=("pool:0",))
        assert [e for e in doc["traceEvents"] if e["ph"] in "sf"] == []
        # consumed but never produced
        doc = self._doc(flow_in="pool:9", flow_ids=("pool:0",))
        assert [e for e in doc["traceEvents"] if e["ph"] in "sf"] == []
        assert validate_chrome_trace(doc) == []

    def test_validator_flags_broken_flow_pairing(self):
        base = {"pid": 1, "tid": 1, "cat": "flow", "name": "realloc"}
        bad = {"traceEvents": [
            dict(base, ph="s", id="a", ts=5.0),
            dict(base, ph="s", id="a", ts=6.0),   # duplicate start
            dict(base, ph="f", id="a", ts=1.0),   # precedes its start
            dict(base, ph="f", id="b", ts=2.0),   # no matching start
            dict(base, ph="s", id="c", ts=0.0),   # start without finish
            dict(base, ph="f", id=True, ts=3.0),  # bool is not a valid id
        ]}
        errors = validate_chrome_trace(bad)
        assert len(errors) == 4 + 1  # the four pairing faults + the bad id
        for needle in ("duplicate flow start", "precedes its start",
                       "no matching 's'", "no matching 'f'",
                       "str/int 'id'"):
            assert any(needle in e for e in errors), (needle, errors)

    def test_golden_cluster_trace_carries_matched_flows(self):
        tr = Tracer()
        trace = load_trace(os.path.join(DATA, "golden_trace.json"))
        ClusterSim(cap_bps=50 * GBPS, policy=Policy.CAL_STALL_OPT,
                   margin_bps=PAPER_MARGIN_BPS, tracer=tr).run(trace)
        doc = to_chrome_trace(tr)
        assert validate_chrome_trace(doc) == []
        starts = {e["id"] for e in doc["traceEvents"] if e["ph"] == "s"}
        finishes = {e["id"] for e in doc["traceEvents"] if e["ph"] == "f"}
        assert starts and starts == finishes  # every arrow has both ends


# ---------------------------------------------------------------------------
# Perf-trajectory regression gate
# ---------------------------------------------------------------------------
CSV = [
    "name,us_per_call,derived",
    "cluster/n16/equal,123.45,added_ttft_ms=1963;p95_ms=8812;"
    "goodput_rps=1.71;policy=equal",
    "cluster/n16/cal,88.00,added_ttft_ms=1100;p95_ms=8878;"
    "goodput_rps=1.80;policy=cal",
]


def _doc(rows=None):
    return regress.bench_result("bench_x", rows_from_csv(CSV)
                                if rows is None else rows)


class TestRegressParsing:
    def test_rows_from_csv_skips_header_and_parses_metrics(self):
        rows = rows_from_csv(CSV)
        assert len(rows) == 2  # header dropped
        assert rows[0]["name"] == "cluster/n16/equal"
        assert rows[0]["us_per_call"] == 123.45
        m = rows[0]["metrics"]
        assert m["added_ttft_ms"] == 1963.0 and m["policy"] == "equal"

    def test_parse_derived_tolerates_junk(self):
        assert parse_derived("a=1;;b=x;noequals; c = 2 ") \
            == {"a": 1.0, "b": "x", "c": 2.0}

    def test_metric_direction(self):
        assert metric_direction("ttft_p95_ms") == -1
        assert metric_direction("us_per_call") == -1
        assert metric_direction("egress_gb") == -1
        assert metric_direction("goodput_rps") == +1
        assert metric_direction("hot_rate") == +1  # rate beats the _s suffix
        assert metric_direction("p95_reduction_x") == +1
        assert metric_direction("policy") == 0

    def test_schema_validation(self):
        assert validate_bench_result(_doc()) == []
        assert validate_bench_result([]) != []
        assert validate_bench_result({"schema": "v0"})
        bad = _doc()
        bad["rows"][0]["metrics"]["x"] = [1, 2]
        assert any("metrics" in v for v in validate_bench_result(bad))
        with pytest.raises(ValueError):
            regress.write_bench_result("/dev/null", {"schema": "nope"})

    def test_write_read_roundtrip(self, tmp_path):
        p = tmp_path / "BENCH_x.json"
        regress.write_bench_result(str(p), _doc())
        with open(p) as f:
            assert json.load(f) == _doc()


class TestRegressCompare:
    def test_unmodified_rerun_is_all_pass(self):
        deltas = compare(_doc(), _doc())
        assert deltas and all(d.status == regress.PASS for d in deltas)

    def test_twenty_percent_ttft_regression_flags(self):
        cur = _doc()
        cur["rows"][1]["metrics"]["p95_ms"] *= 1.20
        deltas = compare(_doc(), cur)
        (flag,) = [d for d in deltas if d.status != regress.PASS]
        assert flag.status == regress.REGRESSION
        assert flag.metric == "p95_ms" and flag.row == "cluster/n16/cal"
        assert flag.rel_change == pytest.approx(0.20)

    def test_direction_governs_regression_vs_improvement(self):
        cur = _doc()
        cur["rows"][0]["metrics"]["goodput_rps"] *= 0.5  # higher-better drop
        cur["rows"][1]["metrics"]["added_ttft_ms"] *= 0.5  # lower-better drop
        by = {(d.row, d.metric): d.status for d in compare(_doc(), cur)}
        assert by[("cluster/n16/equal", "goodput_rps")] == regress.REGRESSION
        assert by[("cluster/n16/cal", "added_ttft_ms")] \
            == regress.IMPROVEMENT

    def test_noise_band_and_abs_floor_suppress_flags(self):
        cur = _doc()
        cur["rows"][0]["metrics"]["p95_ms"] *= 1.05  # inside the 10% band
        assert all(d.status == regress.PASS for d in compare(_doc(), cur))
        cur = _doc()
        cur["rows"][0]["metrics"]["p95_ms"] += 2.0
        # tight band but the absolute change is under the floor
        deltas = compare(_doc(), cur, band=1e-6, abs_floor=10.0)
        assert all(d.status == regress.PASS for d in deltas)

    def test_string_and_unknown_direction_changes_are_drift(self):
        cur = _doc()
        cur["rows"][0]["metrics"]["policy"] = "other"
        by = {(d.row, d.metric): d.status for d in compare(_doc(), cur)}
        assert by[("cluster/n16/equal", "policy")] == regress.DRIFT

    def test_new_and_missing_rows_and_metrics(self):
        cur = _doc()
        cur["rows"] = [cur["rows"][0]]  # second row vanished
        cur["rows"][0]["metrics"]["brand_new"] = 1.0
        del cur["rows"][0]["metrics"]["goodput_rps"]
        statuses = {(d.row, d.metric): d.status for d in compare(_doc(), cur)}
        assert statuses[("cluster/n16/cal", "<row>")] == regress.MISSING
        assert statuses[("cluster/n16/equal", "brand_new")] == regress.NEW
        assert statuses[("cluster/n16/equal", "goodput_rps")] \
            == regress.MISSING

    def test_timings_skipped_unless_asked(self):
        cur = _doc()
        cur["rows"][0]["us_per_call"] *= 100.0  # CI machine noise
        assert all(d.status == regress.PASS for d in compare(_doc(), cur))
        deltas = compare(_doc(), cur, timings=True)
        assert any(d.metric == "us_per_call"
                   and d.status == regress.REGRESSION for d in deltas)

    def test_format_report_counts_and_lists_flags(self):
        cur = _doc()
        cur["rows"][1]["metrics"]["p95_ms"] *= 1.5
        out = regress.format_report("bench_x", compare(_doc(), cur))
        assert out.startswith("bench_x:")
        assert "1 regression" in out and "p95_ms" in out


class TestRegressCLI:
    def _write(self, path, doc):
        regress.write_bench_result(str(path), doc)

    def test_gate_flags_injected_regression_passes_rerun(self, tmp_path,
                                                         capsys):
        base_dir = tmp_path / "trajectory"
        base_dir.mkdir()
        self._write(base_dir / "BENCH_x.json", _doc())
        cur = tmp_path / "BENCH_x.json"
        self._write(cur, _doc())
        # unmodified re-run: clean under --gate
        assert regress.main(["--baseline", str(base_dir), "--gate",
                             str(cur)]) == 0
        assert "pass" in capsys.readouterr().out
        # injected 20% TTFT regression: flagged, and --gate exits nonzero
        bad = _doc()
        bad["rows"][1]["metrics"]["p95_ms"] *= 1.20
        self._write(cur, bad)
        assert regress.main(["--baseline", str(base_dir), str(cur)]) == 0
        assert regress.main(["--baseline", str(base_dir), "--gate",
                             str(cur)]) == 1
        out = capsys.readouterr().out
        assert "regression" in out and "p95_ms" in out

    def test_missing_baseline_starts_the_trajectory(self, tmp_path, capsys):
        base_dir = tmp_path / "trajectory"
        base_dir.mkdir()
        cur = tmp_path / "BENCH_y.json"
        self._write(cur, _doc())
        assert regress.main(["--baseline", str(base_dir), "--gate",
                             str(cur)]) == 0
        assert "trajectory starts here" in capsys.readouterr().out

    def test_usage_error(self, capsys):
        assert regress.main([]) == 2
        assert "usage" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# Tenant labels in the metrics registry
# ---------------------------------------------------------------------------
class TestTenantLabels:
    def test_labeled_name_folding(self):
        assert labeled("ttft") == "ttft"
        assert labeled("ttft", "acme") == "ttft{tenant=acme}"

    def test_labeled_instruments_share_namespace_and_lock(self):
        reg = MetricsRegistry()
        plain = reg.histogram("engine.ttft_s")
        acme = reg.histogram("engine.ttft_s", tenant="acme")
        assert plain is not acme
        assert reg.histogram("engine.ttft_s", tenant="acme") is acme
        reg.counter("engine.requests", tenant="acme").inc()
        reg.gauge("pool.flows", tenant="beta").set(2.0)
        acme.observe(1.0)
        snap = reg.snapshot()
        assert "engine.ttft_s{tenant=acme}" in snap["histograms"]
        assert snap["counters"]["engine.requests{tenant=acme}"] == 1
        assert reg.tenants("engine.ttft_s") == ["acme"]
        assert reg.tenants("engine.requests") == ["acme"]
        assert reg.tenants("pool.flows") == ["beta"]
        assert reg.tenants("nope") == []

    def test_concurrent_tenant_adds_snapshot_consistently(self):
        """Torn-snapshot extension: per-tenant StatGroups under one
        registry keep the paired-field invariant per tenant AND the
        whole-registry snapshot stays a single consistent cut."""
        reg = MetricsRegistry()
        tenants = ("acme", "beta")
        groups = {t: reg.group("engine", ("reused", "computed"), tenant=t)
                  for t in tenants}
        PROMPT, N = 64, 200
        torn, stop = [], threading.Event()

        def reader():
            while not stop.is_set():
                snap = reg.snapshot()["counters"]
                for t in tenants:
                    pair = (snap.get(f"engine{{tenant={t}}}.reused", 0)
                            + snap.get(f"engine{{tenant={t}}}.computed", 0))
                    if pair % PROMPT:
                        torn.append((t, pair))

        readers = [threading.Thread(target=reader) for _ in range(2)]
        for r in readers:
            r.start()

        def writer(tenant, seed):
            g = groups[tenant]
            for i in range(N):
                reused = (seed * 31 + i) % PROMPT
                g.add(reused=reused, computed=PROMPT - reused)

        writers = [threading.Thread(target=writer, args=(t, s))
                   for s, t in enumerate(tenants * 2)]
        for w in writers:
            w.start()
        for w in writers:
            w.join()
        stop.set()
        for r in readers:
            r.join()
        assert not torn
        for t in tenants:
            s = groups[t].snapshot()
            assert s["reused"] + s["computed"] == 2 * N * PROMPT


# ---------------------------------------------------------------------------
# Zero perturbation with monitors attached + fleet rollup
# ---------------------------------------------------------------------------
def _run_golden_cluster(tracer=None, monitor=None, slo=None):
    trace = load_trace(os.path.join(DATA, "golden_trace.json"))
    sim = ClusterSim(cap_bps=50 * GBPS, policy=Policy.CAL_STALL_OPT,
                     margin_bps=PAPER_MARGIN_BPS, tracer=tracer,
                     monitor=monitor, slo=slo)
    return sim.run(trace)


def _run_golden_fleet(tracer=None, monitor=None, slo=None):
    trace = load_trace(os.path.join(DATA, "golden_trace_fleet.json"))
    sim = FleetSim(2, make_router("affinity"),
                   cache=CacheConfig(hot_capacity_bytes=2 * 1024 ** 3,
                                     policy="lru"),
                   cap_bps=20 * GBPS, max_flows=8, tracer=tracer,
                   monitor=monitor, slo=slo)
    return sim, sim.run(trace)


def _record_key(r):
    return (r.req_id, r.arrival_s, r.admit_s, r.flow_done_s,
            r.prefill_done_s, r.bytes_total, r.layer_compute_s, r.replanned)


class TestMonitoredGoldenCluster:
    def test_monitor_and_slo_change_no_simulated_timestamp(self):
        bare = _run_golden_cluster()
        tr = Tracer()
        monitor = StreamMonitor(width_s=1.0, ewma_half_life_s=5.0)
        slo = SLOMonitor([SLOTarget(added_ttft_s=0.5, goal=0.9)],
                         width_s=1.0)
        monitored = _run_golden_cluster(tracer=tr, monitor=monitor, slo=slo)
        assert ([_record_key(r) for r in bare.records]
                == [_record_key(r) for r in monitored.records])
        assert bare.events == monitored.events
        assert bare.reallocs == monitored.reallocs
        # and the observers actually observed: per-window TTFT series exist
        done = sum(1 for r in monitored.records if r.done)
        assert monitor.series("ttft_s").total().count == done > 0
        assert len(monitor.series("ttft_s").windows()) >= 1
        assert monitor.series("pool.reallocs").total().count \
            == monitored.reallocs
        assert slo.status()[""]["total"] == done
        # slo instants (if any) landed on the shared tracer's slo track
        assert slo.tracer is tr

    def test_golden_export_with_monitors_stays_schema_valid(self, tmp_path):
        tr = Tracer()
        _run_golden_cluster(tracer=tr, monitor=StreamMonitor(),
                            slo=SLOMonitor([SLOTarget(ttft_s=1e-6)]))
        doc = to_chrome_trace(tr)
        assert validate_chrome_trace(doc) == []
        # the absurd 1 µs target breaches immediately: instants on "slo"
        assert tr.instants("slo", "slo_breach")


class TestMonitoredGoldenFleet:
    def test_monitor_changes_no_fleet_timestamp(self):
        _, bare = _run_golden_fleet()
        _, monitored = _run_golden_fleet(monitor=StreamMonitor(width_s=1.0))
        ka = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in bare.records]
        kb = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in monitored.records]
        assert ka == kb
        assert bare.global_chunks == monitored.global_chunks

    def test_rollup_is_node_order_invariant_and_complete(self):
        sim, res = _run_golden_fleet(monitor=StreamMonitor(width_s=1.0))
        rollup = sim.monitor_rollup()
        rev = StreamMonitor.merged(list(reversed(sim.node_monitors)))
        assert rollup.snapshot() == rev.snapshot()
        done = sum(1 for r in res.records if r.done)
        assert rollup.series("ttft_s").total().count == done > 0
        # per-node monitors hold only their node's share
        per_node = [m.series("ttft_s").total().count
                    for m in sim.node_monitors]
        assert sum(per_node) == done and all(c < done for c in per_node)
        # rollup inputs untouched
        assert sim.node_monitors[0].series("ttft_s").total().count \
            == per_node[0]

    def test_fleet_slo_is_global_and_tenantwise(self):
        slo = SLOMonitor([SLOTarget(ttft_s=1e-6)])  # everything is bad
        _, res = _run_golden_fleet(slo=slo)
        done = sum(1 for r in res.records if r.done)
        assert slo.status()[""]["total"] == done
        assert slo.status()[""]["bad"] == done

    def test_rollup_without_monitor_raises(self):
        sim, _ = _run_golden_fleet()
        with pytest.raises(ValueError):
            sim.monitor_rollup()
