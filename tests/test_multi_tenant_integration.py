"""End-to-end multi-tenant serving: several engines share one object store
and one BandwidthPool; the scheduler's epoch semantics drive real transfers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import (FlowRequest, Gateway, InMemoryStore, Policy,
                        RadixIndex)
from repro.core.scheduler import BandwidthPool
from repro.models import build_model
from repro.serving import Orchestrator, ServingEngine


def _mk(store, index, model, params, cap=None):
    cfg = model.cfg
    spec = cfg.kv_spec(8, dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize)
    orch = Orchestrator(index, Gateway(store), spec, theta_bytes=0,
                        bandwidth_cap=cap, policy=Policy.CAL_STALL_OPT)
    return ServingEngine(model, params, orch)


class TestSharedStoreMultiTenant:
    def test_tenants_share_prefix_chunks_across_engines(self):
        """Two serving nodes (engines) with a SHARED object tier + radix
        index: node B reuses chunks node A produced — the paper's core
        stateless-worker property (§3, Fig. 5)."""
        cfg = get_smoke_config("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        store, index = InMemoryStore(), RadixIndex(8)
        node_a = _mk(store, index, model, params)
        node_b = _mk(store, index, model, params)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, 200, size=40)
        ra = node_a.submit(prompt, "a")
        rb = node_b.submit(prompt, "b")  # different node, same prefix pool
        assert not ra.hit and rb.hit and rb.matched_tokens == 32
        np.testing.assert_allclose(rb.logits, ra.logits, rtol=1e-4, atol=1e-4)

    def test_contended_rates_follow_stall_opt(self):
        """Under a shared cap, concurrent layerwise requests receive
        Stall-opt rates and the slower allocation yields larger transfer
        completion — the scheduler actually shapes real transfers."""
        cfg = get_smoke_config("qwen3-0.6b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        store, index = InMemoryStore(), RadixIndex(8)
        rng = np.random.default_rng(1)
        long_p = rng.integers(0, 200, size=64)
        short_p = rng.integers(0, 200, size=24)
        warm = _mk(store, index, model, params)
        warm.submit(long_p, "w1"), warm.submit(short_p, "w2")

        cap = 2e5  # tight shared budget (B/s)
        engine = _mk(store, index, model, params, cap=cap)
        # an already-active tenant holds part of the budget
        active = [FlowRequest("other", 5e4, 1e-3, cfg.num_layers)]
        plan_long = engine.orch.plan(long_p, 1e-3, active=active, req_id="L")
        plan_short = engine.orch.plan(short_p, 1e-3, active=active, req_id="S")
        assert plan_long.rate is not None and plan_short.rate is not None
        total = plan_long.rate  # each planned against the same pool
        assert plan_long.rate <= cap
        # bigger per-layer payload => larger sqrt-waterfill share
        assert plan_long.rate > plan_short.rate

    def test_epoch_pool_drives_engine_rates(self):
        """BandwidthPool epochs: a finishing flow's bandwidth only returns
        at the next epoch; new admissions rebalance real allocations."""
        pool = BandwidthPool(budget=1000.0, policy=Policy.STALL_OPT)
        pool.submit(FlowRequest("a", 100.0, 0.5, 4))  # r* = 200
        pool.submit(FlowRequest("b", 400.0, 0.5, 4))  # r* = 800
        alloc = pool.start_epoch(0.0)
        assert alloc["a"] + alloc["b"] <= 1000.0 + 1e-9
        assert alloc["b"] > alloc["a"]
        done = pool.advance(10.0)
        assert set(done) == {"a", "b"}
        pool.submit(FlowRequest("c", 100.0, 1.0, 4))
        alloc2 = pool.start_epoch(1.0)
        assert list(alloc2) == ["c"]  # finished flows released at boundary
