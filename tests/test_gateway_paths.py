"""Gateway S3-path matrix (paper §4.1, Fig. 9).

Previously only exercised indirectly through the serving stack: every one of
the five S3-compatible paths must return byte-identical data (the path
changes *how* bytes move, never *what* bytes arrive), and the calibrated
profiles must rank exactly as the paper measures them — every hop from S3TCP
to S3RDMA-Agg strictly improves single-object latency.
"""
import numpy as np
import pytest

from repro.core import (Delivery, Gateway, InMemoryStore, KVSpec, chunk_keys,
                        make_descriptor)
from repro.core.gateway import S3Path
from repro.core.transport import PROFILES

# Fig. 9's ordering: each step removes a bottleneck (TCP streaming ->
# gateway staging -> per-object metadata -> descriptor-side metadata).
ORDERED = [S3Path.TCP, S3Path.RDMA_BUFFER, S3Path.RDMA_DIRECT,
           S3Path.RDMA_BATCH, S3Path.RDMA_AGG]
SIZES = [4 * 1024, 256 * 1024, 4 * 1024 * 1024]


def _gateway_with(data: dict[bytes, bytes]) -> Gateway:
    store = InMemoryStore()
    for k, v in data.items():
        store.put(k, v)
    return Gateway(store)


class TestPathMatrix:
    @pytest.mark.parametrize("size", SIZES)
    def test_all_paths_return_identical_bytes(self, size):
        rng = np.random.default_rng(size)
        blob = rng.bytes(size)
        gw = _gateway_with({b"k" * 16: blob})
        results = {path: gw.get(b"k" * 16, path=path) for path in ORDERED}
        for path, res in results.items():
            assert res.data == blob, f"{path} corrupted payload"

    @pytest.mark.parametrize("size", SIZES)
    def test_single_get_timing_strictly_improves(self, size):
        gw = _gateway_with({b"k" * 16: b"\x5a" * size})
        totals = [gw.get(b"k" * 16, path=p).timing.total_s for p in ORDERED]
        for prev, cur, p_prev, p_cur in zip(totals, totals[1:],
                                            ORDERED, ORDERED[1:]):
            assert cur < prev, (
                f"{p_cur.value} ({cur:.6f}s) not faster than "
                f"{p_prev.value} ({prev:.6f}s) at {size}B")

    def test_range_get_identical_across_paths(self):
        rng = np.random.default_rng(7)
        blob = rng.bytes(64 * 1024)
        gw = _gateway_with({b"r" * 16: blob})
        want = blob[1000:9000]
        for path in ORDERED:
            assert gw.range_get(b"r" * 16, 1000, 8000, path=path).data == want

    def test_batch_get_matches_single_gets(self):
        rng = np.random.default_rng(8)
        objs = {bytes([i]) * 16: rng.bytes(32 * 1024) for i in range(4)}
        gw = _gateway_with(objs)
        keys = list(objs)
        datas, timing = gw.batch_get(keys)
        assert datas == [objs[k] for k in keys]
        # one batched request beats four per-object requests on any path
        singles = sum(gw.get(k, path=S3Path.RDMA_DIRECT).timing.total_s
                      for k in keys)
        assert timing.total_s < singles

    def test_objectcache_get_equals_store_slices(self):
        """The descriptor path (S3RDMA-Agg) returns exactly the stored
        layer slices, re-ordered layer-major — same bytes as any other path
        would deliver, just aggregated."""
        spec = KVSpec(num_layers=4, chunk_tokens=8, num_kv_heads=2,
                      head_dim=4, dtype_bytes=2)
        rng = np.random.default_rng(9)
        keys = chunk_keys(np.arange(3 * spec.chunk_tokens), spec.chunk_tokens)
        objs = {k: rng.bytes(spec.chunk_bytes) for k in keys}
        gw = _gateway_with(objs)
        desc = make_descriptor(keys, spec, Delivery.LAYERWISE)
        res = gw.objectcache_get(desc.to_wire())
        S = spec.per_layer_chunk_bytes
        for l, payload in enumerate(res.payloads):
            assert payload == b"".join(objs[k][l * S:(l + 1) * S]
                                       for k in keys)

    def test_profiles_cover_all_paths(self):
        gw = _gateway_with({})
        assert set(gw.profiles) == set(S3Path)
        for path, prof in gw.profiles.items():
            assert prof.name in PROFILES
