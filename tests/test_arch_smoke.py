"""Per-architecture smoke tests: a REDUCED config of the same family runs one
train step (loss + grads) and one prefill+decode step on CPU, asserting output
shapes and no NaNs.  Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model

ALL_ARCHS = ARCH_IDS + ["llama3-1-8b"]


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["embeds"] = jax.random.normal(key, (B, 12, cfg.d_model),
                                            jnp.dtype(cfg.compute_dtype))
        batch["tokens"] = batch["tokens"][:, :8]
        batch["labels"] = batch["labels"][:, :8]
    elif cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init_params(key)
    batch = _batch(cfg, key)
    val, grads = jax.value_and_grad(lambda p: model.loss(p, batch))(params)
    assert np.isfinite(val), arch
    # rough ln(V) sanity at init
    assert 0.5 * np.log(cfg.vocab_size) < val < 2.5 * np.log(cfg.padded_vocab)
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g, dtype=np.float32)).all()
                          for g in leaves), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init_params(key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    lg, cache = model.prefill(params, batch)
    assert lg.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch

    # grow attention caches to make room for the new token, then decode once
    plen = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        plen += cfg.num_patches

    def grow(a):
        if a.ndim >= 4 and a.shape[3] == plen and jnp.issubdtype(a.dtype, jnp.floating):
            pad = [(0, 0)] * a.ndim
            pad[3] = (0, 4)
            return jnp.pad(a, pad)
        return a

    if cfg.family in ("dense", "vlm", "moe"):
        cache = grow(cache)
    elif cfg.family == "hybrid":
        cache = {**cache, "attn": grow(cache["attn"])}
    elif cfg.family == "encdec":
        cache = {**cache, "self": grow(cache["self"])}
    token = batch["tokens"][:, -1:]
    pos = jnp.full((B,), plen, jnp.int32)
    lg2, cache2 = model.decode_step(params, cache, token, pos)
    assert lg2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg2, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_count(arch):
    """The FULL configs' analytic parameter counts hit the advertised sizes
    (no allocation — pure arithmetic)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "qwen3-0.6b": 0.6e9, "smollm-135m": 0.135e9, "gemma-2b": 2.5e9,
        "qwen3-14b": 14e9, "whisper-large-v3": 1.5e9, "mamba2-2.7b": 2.7e9,
        "qwen3-moe-30b-a3b": 30e9, "llama4-maverick-400b-a17b": 400e9,
        "zamba2-1.2b": 1.2e9, "internvl2-26b": 20e9,  # LM backbone only (ViT stubbed)
        "llama3-1-8b": 8e9,
    }[arch]
    assert 0.5 * expected < n < 1.8 * expected, (arch, n / 1e9)


@pytest.mark.parametrize("arch", ["qwen3-moe-30b-a3b", "llama4-maverick-400b-a17b"])
def test_moe_active_params(arch):
    cfg = get_config(arch)
    active = cfg.active_param_count()
    expected = {"qwen3-moe-30b-a3b": 3e9, "llama4-maverick-400b-a17b": 17e9}[arch]
    assert 0.5 * expected < active < 2.0 * expected, (arch, active / 1e9)
