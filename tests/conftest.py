import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:  # container has no hypothesis and pip is off-limits
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub
    _hypothesis_stub.install()
