"""The §Perf optimization variants must be numerically equivalent to the
baseline implementations (the tiling/sharding changes the schedule, never the
math)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models import layers as nn
from repro.models.layers import (_decode_scores_blocked, attention_scores,
                                 attention_scores_blocked)


class TestBlockedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("q_offset", [0, 8])
    def test_matches_naive(self, causal, q_offset):
        key = jax.random.PRNGKey(0)
        B, Sq, H, dh = 2, 16, 4, 8
        Sk = Sq + q_offset
        q = jax.random.normal(key, (B, Sq, H, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sk, H, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sk, H, dh))
        if causal:
            iq = jnp.arange(Sq)[:, None] + q_offset
            mask = (jnp.arange(Sk)[None, :] <= iq)[None, None]
        else:
            mask = jnp.ones((1, 1, Sq, Sk), bool)
        want = attention_scores(q, k, v, mask)
        got = attention_scores_blocked(q, k, v, causal=causal,
                                       q_offset=q_offset, block_k=4)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @given(st.sampled_from([2, 4, 8, 16]), st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_property_block_size_invariance(self, bk, seed):
        key = jax.random.PRNGKey(seed)
        q = jax.random.normal(key, (1, 16, 2, 8))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, 2, 8))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 16, 2, 8))
        a = attention_scores_blocked(q, k, v, causal=True, q_offset=0,
                                     block_k=bk)
        b = attention_scores_blocked(q, k, v, causal=True, q_offset=0,
                                     block_k=16)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    def test_gradients_match(self):
        """Rematted blocked backward == naive backward."""
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (1, 8, 2, 4))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 2, 4))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 2, 4))
        iq = jnp.arange(8)[:, None]
        mask = (jnp.arange(8)[None, :] <= iq)[None, None]
        g1 = jax.grad(lambda q: attention_scores(q, k, v, mask).sum())(q)
        g2 = jax.grad(lambda q: attention_scores_blocked(
            q, k, v, causal=True, q_offset=0, block_k=4).sum())(q)
        np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)


class TestBlockedDecode:
    @pytest.mark.parametrize("nb", [2, 4, 8])
    def test_matches_ref(self, nb):
        from repro.kernels import ref
        key = jax.random.PRNGKey(1)
        B, H, KV, S, dh = 3, 4, 2, 32, 8
        q = jax.random.normal(key, (B, H, dh))
        kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, dh))
        vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, dh))
        pos = jnp.array([5, 31, 16])
        got = _decode_scores_blocked(q, kc, vc, pos, nb)
        want = ref.ref_decode_attention(q, kc, vc, pos + 1)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def _opt_cfg(cfg):
    return dataclasses.replace(cfg, attn_impl="blocked", attn_block_k=8,
                               decode_impl="blocked", decode_blocks=4)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen3-moe-30b-a3b",
                                  "zamba2-1.2b", "whisper-large-v3"])
class TestEndToEndVariants:
    def test_loss_and_decode_equal(self, arch):
        base_cfg = get_smoke_config(arch)
        opt_cfg = _opt_cfg(base_cfg)
        base, opt = build_model(base_cfg), build_model(opt_cfg)
        key = jax.random.PRNGKey(0)
        params = base.init_params(key)  # identical param structure
        B, S = 2, 16
        batch = {"tokens": jax.random.randint(key, (B, S), 0,
                                              base_cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        if base_cfg.family == "encdec":
            batch["embeds"] = jax.random.normal(key, (B, 12, base_cfg.d_model))
            batch["tokens"] = batch["tokens"][:, :8]
            batch["labels"] = batch["labels"][:, :8]
        l1 = base.loss(params, batch)
        l2 = opt.loss(params, batch)
        assert abs(float(l1) - float(l2)) < 2e-4, (arch, float(l1), float(l2))

        lg1, c1 = base.prefill(params, batch)
        lg2, c2 = opt.prefill(params, batch)
        np.testing.assert_allclose(np.asarray(lg1, np.float32),
                                   np.asarray(lg2, np.float32),
                                   rtol=2e-3, atol=2e-3)
        tok = batch["tokens"][:, -1:]
        plen = batch["tokens"].shape[1]
        pos = jnp.full((B,), plen, jnp.int32)

        def grow(a):
            if a.ndim >= 4 and a.shape[3] == plen and \
                    jnp.issubdtype(a.dtype, jnp.floating):
                pad = [(0, 0)] * a.ndim
                pad[3] = (0, 4)
                return jnp.pad(a, pad)
            return a
        if base_cfg.family in ("dense", "vlm", "moe"):
            c1, c2 = grow(c1), grow(c2)
        elif base_cfg.family == "hybrid":
            c1 = {**c1, "attn": grow(c1["attn"])}
            c2 = {**c2, "attn": grow(c2["attn"])}
        elif base_cfg.family == "encdec":
            c1 = {**c1, "self": grow(c1["self"])}
            c2 = {**c2, "self": grow(c2["self"])}
        d1, _ = base.decode_step(params, c1, tok, pos)
        d2, _ = opt.decode_step(params, c2, tok, pos)
        np.testing.assert_allclose(np.asarray(d1, np.float32),
                                   np.asarray(d2, np.float32),
                                   rtol=2e-3, atol=2e-3)
