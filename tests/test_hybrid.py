"""Compute-or-load hybrid prefill (DESIGN.md §Compute-or-load).

Planner: endpoint correctness against the layerwise simulator and the
full-prefill compute model, closed-form == exhaustive, monotone Cake-style
crossover under a bandwidth sweep.  Policy: the BandwidthPool re-planning
hook shrinks stalling flows.  Engine: `_serve_hybrid` logits are bit-for-bit
equal to a no-cache prefill on smollm-135m.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (BandwidthPool, Delivery, FlowRequest, Gateway,
                        InMemoryStore, MeasuredCompute, PaperComputeModel,
                        Policy, RadixIndex)
from repro.core.scheduler import per_layer_stall
from repro.core.simulator import ServingSimulator, WorkloadRequest
from repro.core.transport import LOCAL_DRAM, S3_RDMA_AGG, S3_TCP
from repro.hybrid import (HybridPlanner, HybridReplanner, crossover_sweep,
                          hybrid_workload_ttft, plan_split, validate_split)
from repro.models import build_model
from repro.serving import Orchestrator, ServingEngine

GBPS = 1e9 / 8
GRID = [(4096, 0.5), (16384, 0.875), (32768, 0.5), (65536, 0.875)]
RATES = [0.1, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 100.0]  # Gbps


def _setup(ctx, hit, G=64):
    sim = ServingSimulator()
    w = WorkloadRequest(f"{ctx}/{hit}", ctx, hit, G)
    return sim, w, sim.kv_spec(G), w.cached_tokens // G


class TestPlannerEndpoints:
    @pytest.mark.parametrize("ctx,hit", GRID)
    def test_pure_fetch_endpoint_equals_layerwise_ttft(self, ctx, hit):
        """T(n) must equal the simulator's pure layerwise path exactly."""
        sim, w, spec, n = _setup(ctx, hit)
        m = PaperComputeModel()
        for rate in (None, 1.0 * GBPS, 8.0 * GBPS):
            split = plan_split(ctx, n, spec, m, S3_RDMA_AGG, rate)
            want = sim.ttft_layerwise(w, S3_RDMA_AGG, rate_limit=rate).ttft_s
            assert split.fetch_ttft_s == pytest.approx(want, abs=1e-12)

    @pytest.mark.parametrize("ctx,hit", GRID)
    def test_pure_recompute_endpoint_equals_full_prefill(self, ctx, hit):
        """T(0) must equal the full-context prefill compute time."""
        sim, w, spec, n = _setup(ctx, hit)
        m = PaperComputeModel()
        split = plan_split(ctx, n, spec, m, S3_RDMA_AGG, 1.0 * GBPS)
        assert split.recompute_ttft_s == pytest.approx(
            m.suffix_compute_s(ctx, 0.0), rel=1e-12)
        assert split.recompute_ttft_s == pytest.approx(
            sim.ttft_recompute(w).ttft_s, rel=1e-12)

    def test_split_accounting(self):
        _, _, spec, n = _setup(16384, 0.875)
        split = plan_split(16384, n, spec, PaperComputeModel(), S3_RDMA_AGG,
                           1.0 * GBPS)
        assert 0 <= split.fetch_chunks <= n
        assert split.recompute_chunks == n - split.fetch_chunks
        assert split.bytes_per_layer == \
            split.fetch_chunks * spec.per_layer_chunk_bytes


class TestPlannerOptimality:
    @pytest.mark.parametrize("ctx,hit", GRID)
    def test_hybrid_never_worse_than_either_endpoint(self, ctx, hit):
        _, _, spec, n = _setup(ctx, hit)
        m = PaperComputeModel()
        for rate in RATES:
            s = plan_split(ctx, n, spec, m, S3_RDMA_AGG, rate * GBPS)
            assert s.ttft_s <= min(s.fetch_ttft_s, s.recompute_ttft_s) + 1e-12

    @pytest.mark.parametrize("ctx,hit", GRID)
    @pytest.mark.parametrize("profile", [S3_RDMA_AGG, S3_TCP, LOCAL_DRAM],
                             ids=lambda p: p.name)
    def test_closed_form_matches_exhaustive(self, ctx, hit, profile):
        """The closed form is exact: the objective is convex on [1, n]."""
        _, _, spec, n = _setup(ctx, hit)
        m = PaperComputeModel()
        for rate in (None, 0.25 * GBPS, 1.0 * GBPS, 8.0 * GBPS, 64.0 * GBPS):
            cf, ex = validate_split(ctx, n, spec, m, profile, rate)
            assert cf.ttft_s == pytest.approx(ex.ttft_s, abs=1e-12), \
                (profile.name, rate, cf.fetch_chunks, ex.fetch_chunks)

    def test_closed_form_also_exact_for_measured_compute(self):
        spec = ServingSimulator().kv_spec(64)
        m = MeasuredCompute(num_layers=32, base_s=1e-5, per_token_s=2e-6,
                            bytes_per_token_per_layer=4096)
        for rate in RATES:
            cf, ex = validate_split(16384, 224, spec, m, S3_RDMA_AGG,
                                    rate * GBPS)
            assert cf.ttft_s == pytest.approx(ex.ttft_s, abs=1e-12)

    @pytest.mark.parametrize("compute", [
        PaperComputeModel(),
        MeasuredCompute(num_layers=32, base_s=1e-5, per_token_s=2e-6,
                        bytes_per_token_per_layer=4096)],
        ids=["paper", "measured"])
    def test_closed_form_exact_off_grid(self, compute):
        """Regression: bimodal objectives (concave interpolated compute) and
        fp-noise quadratic coefficients (linear compute) once sent the
        closed form to splits up to 7x worse than optimal at G=16 full
        matches; it must match the exhaustive scan everywhere."""
        from repro.core.types import KVSpec
        for ctx, G, hitfrac in ((32768, 16, 1.0), (65536, 16, 1.0),
                                (65536, 16, 0.5), (65536, 256, 0.875)):
            n = int(ctx * hitfrac) // G
            spec = KVSpec(32, G, 8, 128, 2)
            for rate in (None, 1.0 * GBPS, 4.0 * GBPS, 32.0 * GBPS):
                for profile in (S3_RDMA_AGG, LOCAL_DRAM):
                    cf, ex = validate_split(ctx, n, spec, compute, profile,
                                            rate)
                    assert cf.ttft_s == pytest.approx(ex.ttft_s, abs=1e-12), \
                        (ctx, G, hitfrac, profile.name, rate,
                         cf.fetch_chunks, ex.fetch_chunks)


class TestCrossover:
    @pytest.mark.parametrize("ctx,hit", GRID)
    def test_fetch_fraction_monotone_in_bandwidth(self, ctx, hit):
        """Cake-style crossover: more bandwidth -> fetch at least as much."""
        _, w, _, _ = _setup(ctx, hit)
        rows = crossover_sweep(w, [r * GBPS for r in RATES])
        ms = [r["fetch_chunks"] for r in rows]
        assert all(a <= b for a, b in zip(ms, ms[1:])), ms

    def test_extremes(self):
        """Pure recompute as bandwidth -> 0; pure fetch when unthrottled."""
        _, w, _, _ = _setup(16384, 0.875)
        low = hybrid_workload_ttft(w, rate=0.05 * GBPS)
        assert low.is_pure_recompute
        high = hybrid_workload_ttft(w, rate=None)
        assert high.is_pure_fetch

    def test_zero_rate_degenerates_to_pure_recompute(self):
        """allocate() can hand out rate 0 when the budget is exhausted; the
        planner must not divide by it."""
        _, _, spec, n = _setup(16384, 0.875)
        m = PaperComputeModel()
        s = plan_split(16384, n, spec, m, S3_RDMA_AGG, 0.0)
        assert s.is_pure_recompute
        assert s.ttft_s == pytest.approx(m.suffix_compute_s(16384, 0.0))

    def test_zero_match_degenerates_to_pure_recompute(self):
        _, _, spec, _ = _setup(16384, 0.875)
        s = plan_split(16384, 0, spec, PaperComputeModel(), S3_RDMA_AGG, 1e9)
        assert s.total_chunks == 0 and s.is_pure_recompute

    def test_hybrid_strictly_better_somewhere(self):
        """There is a mid-bandwidth regime where the interior split beats
        both pure strategies — the whole point of compute-or-load."""
        _, w, _, _ = _setup(16384, 0.875)
        rows = crossover_sweep(w, [r * GBPS for r in RATES])
        assert any(r["hybrid_s"] < min(r["fetch_s"], r["recompute_s"]) - 1e-9
                   and 0 < r["fetch_chunks"] < r["total_chunks"]
                   for r in rows), rows


class TestMeasuredCompute:
    def test_fit_recovers_linear_model(self):
        base, per_tok = 2e-4, 3e-6
        samples = [(s, base + per_tok * s) for s in (64, 256, 1024, 4096)]
        m = MeasuredCompute.fit(samples, num_layers=4,
                                bytes_per_token_per_layer=1024)
        assert m.base_s == pytest.approx(base, rel=1e-6)
        assert m.per_token_s == pytest.approx(per_tok, rel=1e-6)
        assert m.layer_compute_s(4096, 0.5) == \
            pytest.approx(base + per_tok * 2048, rel=1e-6)
        assert m.suffix_compute_s(4096, 0.5) == \
            pytest.approx(4 * (base + per_tok * 2048), rel=1e-6)

    def test_degenerate_fit_never_divides_by_zero(self):
        """A single-sample fit has no intercept and a full hit has no suffix:
        the compute window is floored so rate math stays finite."""
        m = MeasuredCompute.fit([(128, 0.004)], num_layers=2,
                                bytes_per_token_per_layer=1024)
        assert m.layer_compute_s(4096, 1.0) > 0.0
        assert np.isfinite(m.required_bw(4096, 1.0))
        with pytest.raises(ValueError):
            MeasuredCompute.fit([], num_layers=2)


class TestReplanningPolicy:
    def _pool(self, budget, replan=True):
        sim = ServingSimulator()
        spec = sim.kv_spec(64)
        rep = HybridReplanner(PaperComputeModel(), S3_RDMA_AGG, spec)
        pool = BandwidthPool(budget=budget, policy=Policy.STALL_OPT,
                             replanner=rep if replan else None)
        ws = [WorkloadRequest("16K,87.5%", 16384, 0.875),
              WorkloadRequest("64K,87.5%", 65536, 0.875)]
        for w in ws:
            rep.register(w.req_id, w.context)
            pool.submit(sim.flow_request(w))
        return sim, pool, ws

    def test_stalling_flows_shrink_demand(self):
        sim, pool, ws = self._pool(5 * GBPS)
        alloc = pool.start_epoch(0.0)
        assert pool.replans > 0
        for w in ws:
            flow = pool._flows[w.req_id]
            orig = sim.flow_request(w)
            assert flow.req.total_bytes <= orig.total_bytes
            # a hybrid request asks for less bandwidth instead of stalling
            assert per_layer_stall(flow.req, alloc[w.req_id]) <= \
                per_layer_stall(orig, alloc[w.req_id]) + 1e-12

    def test_total_stall_improves(self):
        sim, pool, ws = self._pool(5 * GBPS)
        alloc = pool.start_epoch(0.0)
        _, base_pool, _ = self._pool(5 * GBPS, replan=False)
        base = base_pool.start_epoch(0.0)
        stall = sum(per_layer_stall(pool._flows[w.req_id].req,
                                    alloc[w.req_id]) for w in ws)
        stall_base = sum(per_layer_stall(sim.flow_request(w), base[w.req_id])
                         for w in ws)
        assert stall < stall_base

    def test_no_replan_when_unconstrained(self):
        _, pool, ws = self._pool(1000 * GBPS)
        pool.start_epoch(0.0)
        assert pool.replans == 0

    def test_live_flows_keep_their_split(self):
        """Re-planning applies only at admission; a flow mid-transfer is
        never re-split (its bytes are already committed)."""
        sim, pool, ws = self._pool(5 * GBPS)
        pool.start_epoch(0.0)
        replans = pool.replans
        pool.advance(0.01)
        pool.start_epoch(0.1)
        assert pool.replans == replans

    def test_flow_replanned_to_pure_recompute_still_completes(self):
        """A flow whose split degenerates to zero bytes transfers nothing
        but must still be reported done by advance() — callers track request
        completion through that return."""
        sim, pool, ws = self._pool(5 * GBPS)
        pool.start_epoch(0.0)
        zero = [w.req_id for w in ws
                if pool._flows[w.req_id].req.total_bytes == 0]
        assert zero, "expected at least one pure-recompute re-plan"
        done = pool.advance(0.01)
        assert set(zero) <= set(done)
        assert not (set(zero) & set(pool.advance(0.01)))  # reported once

    def test_zero_byte_flow_survives_back_to_back_epochs(self):
        """Even if the epoch turns over before any advance(), a completed
        zero-byte flow must still be reported exactly once."""
        sim, pool, ws = self._pool(5 * GBPS)
        pool.start_epoch(0.0)
        zero = [w.req_id for w in ws
                if pool._flows[w.req_id].req.total_bytes == 0]
        assert zero
        pool.start_epoch(0.1)  # no advance() in between
        done = pool.advance(0.01)
        assert set(zero) <= set(done)
        assert not (set(zero) & set(pool.advance(0.01)))


class TestHybridEngine:
    G = 8

    def _mk(self, cap):
        cfg = get_smoke_config("smollm-135m")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        spec = cfg.kv_spec(self.G,
                           dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize)
        compute = MeasuredCompute(
            num_layers=spec.num_layers, base_s=0.0, per_token_s=1e-4,
            bytes_per_token_per_layer=spec.bytes_per_token_per_layer)
        planner = HybridPlanner(compute, LOCAL_DRAM, session_setup=False)
        orch = Orchestrator(RadixIndex(self.G), Gateway(InMemoryStore()), spec,
                            theta_bytes=0, bandwidth_cap=cap, hybrid=planner)
        return ServingEngine(model, params, orch), orch

    def test_serve_hybrid_bitwise_equals_no_cache_prefill(self):
        """The acceptance bar: hybrid logits == no-cache prefill, bit for bit
        (fp32 smoke model; the recompute-span and suffix go through the same
        kernels, the fetch-span round-trips the object store losslessly)."""
        engine, orch = self._mk(cap=1.28e6)
        rng = np.random.default_rng(0)
        prompt = rng.integers(0, 200, size=48)
        cold = engine.submit(prompt, "cold")  # no-cache prefill
        warm = engine.submit(prompt, "warm")
        assert warm.delivery is Delivery.HYBRID
        assert orch.stats["hybrid_splits"] == 1
        # interior split: some chunks fetched, some recomputed
        assert 0 < warm.matched_tokens < 40
        assert warm.matched_tokens % self.G == 0
        np.testing.assert_array_equal(warm.logits, cold.logits)

    def test_hybrid_decode_matches_no_cache_decode(self):
        engine, _ = self._mk(cap=1.28e6)
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, 200, size=48)
        cold = engine.submit(prompt, "c", max_new_tokens=4)
        warm = engine.submit(prompt, "w", max_new_tokens=4)
        assert warm.delivery is Delivery.HYBRID
        assert cold.new_tokens == warm.new_tokens

    def test_pure_recompute_split_falls_back_to_full_prefill(self):
        """A cap so tight the planner picks m=0: served exactly like a miss,
        counted as a recompute fallback — not a hit, not a hybrid split."""
        engine, orch = self._mk(cap=10.0)  # 10 B/s
        rng = np.random.default_rng(2)
        prompt = rng.integers(0, 200, size=48)
        cold = engine.submit(prompt, "c")
        warm = engine.submit(prompt, "w")
        assert warm.matched_tokens == 0 and warm.delivery is None
        assert orch.stats["hybrid_splits"] == 0
        assert orch.stats["fallbacks"] == 1
        np.testing.assert_array_equal(warm.logits, cold.logits)

    def test_fused_family_honours_the_split(self):
        """Families without layerwise streaming (llama4-style alternating
        MoE) cannot overlap, but the split still governs how many bytes
        move: the fetch-span arrives as whole chunks, the rest recomputes."""
        cfg = get_smoke_config("llama4-maverick-400b-a17b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        spec = cfg.kv_spec(self.G,
                           dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize)
        compute = MeasuredCompute(
            num_layers=spec.num_layers, base_s=0.0, per_token_s=1e-4,
            bytes_per_token_per_layer=spec.bytes_per_token_per_layer)
        orch = Orchestrator(RadixIndex(self.G), Gateway(InMemoryStore()), spec,
                            theta_bytes=0, bandwidth_cap=1.28e6,
                            hybrid=HybridPlanner(compute, LOCAL_DRAM,
                                                 session_setup=False))
        engine = ServingEngine(model, params, orch)
        assert not engine._layerwise_ok
        rng = np.random.default_rng(4)
        prompt = rng.integers(0, 200, size=32)
        cold = engine.submit(prompt, "c")
        warm = engine.submit(prompt, "w")
        assert orch.stats["hybrid_splits"] == 1
        assert warm.delivery is Delivery.CHUNKWISE
        assert 0 < warm.matched_tokens < 24  # a strict sub-span was fetched
        np.testing.assert_allclose(warm.logits, cold.logits,
                                   rtol=1e-4, atol=1e-4)

    def test_unthrottled_stays_layerwise(self):
        """With no cap and fast transport the planner fetches everything —
        the plan degenerates to the ordinary layerwise path."""
        engine, orch = self._mk(cap=None)
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, 200, size=48)
        engine.submit(prompt, "c")
        warm = engine.submit(prompt, "w")
        assert warm.delivery is Delivery.LAYERWISE
        assert orch.stats["hybrid_splits"] == 0
