"""Fleet-scale cache economy (DESIGN.md §Fleet): eviction policies, the
radix-index/store coherence contract, Zipfian workload generators, routing,
and the multi-node fleet simulator — including the 1-node conformance oracle
against `ClusterSim` and the committed golden fleet trace."""
import json
import math
import os
import random
import threading

import pytest

from repro.cluster import (ClosedLoopTrace, ClusterSim, TraceRequest,
                           load_trace, poisson_trace, save_trace, summarize)
from repro.cluster.metrics import RequestRecord, per_tenant
from repro.core.gateway import Gateway
from repro.core.hashing import GENESIS, chunk_keys
from repro.core.object_store import InMemoryStore, TieredStore
from repro.core.radix import RadixIndex
from repro.core.types import KVSpec
from repro.fleet import (AffinityRouter, ConsistentHashRouter, GDSFPolicy,
                         LFUPolicy, LRUPolicy, RandomRouter, RoundRobinRouter,
                         TTLPolicy, make_policy, make_router, rag_trace,
                         tenant_churn_trace, working_set_chunks,
                         zipf_system_prompt_trace)
from repro.fleet.sim import (ByteLedgerStore, CacheConfig, FleetSim,
                             NodeCache, derive_chain, request_chain)
from repro.serving.orchestrator import Orchestrator

DATA = os.path.join(os.path.dirname(__file__), "data")
GBPS = 1e9 / 8


def k(i: int) -> bytes:
    return bytes([i]) * 16


# ---------------------------------------------------------------------------
# Eviction policies
# ---------------------------------------------------------------------------
class TestPolicies:
    def test_lru_victim_order(self):
        p = LRUPolicy()
        for i in range(3):
            p.add(k(i), 1, now=float(i))
        p.touch(k(0), now=5.0)  # 0 becomes most recent
        assert p.pop_victim(6.0) == k(1)
        assert p.pop_victim(6.0) == k(2)
        assert p.pop_victim(6.0) == k(0)
        assert p.pop_victim(6.0) is None

    def test_lfu_frequency_beats_recency(self):
        p = LFUPolicy()
        p.add(k(0), 1, now=0.0)
        p.add(k(1), 1, now=1.0)
        for _ in range(3):
            p.touch(k(0), now=2.0)
        # k1 is more recent in LRU terms but colder in frequency
        assert p.pop_victim(3.0) == k(1)
        assert p.pop_victim(3.0) == k(0)

    def test_lfu_min_freq_recovers_after_removals(self):
        p = LFUPolicy()
        p.add(k(0), 1, now=0.0)
        p.touch(k(0), now=1.0)
        p.add(k(1), 1, now=2.0)
        assert p.remove(k(1))  # empties the freq-1 bucket
        assert p.pop_victim(3.0) == k(0)  # must advance past the hole

    def test_ttl_expiry_and_refresh(self):
        p = TTLPolicy(ttl_s=10.0)
        p.add(k(0), 1, now=0.0)
        p.add(k(1), 1, now=0.0)
        p.touch(k(0), now=8.0)  # refresh pushes the deadline out
        assert p.expired(11.0) == [k(1)]
        assert p.expired(11.0) == []  # drained
        assert p.expired(19.0) == [k(0)]

    def test_gdsf_prefers_evicting_large_cold_objects(self):
        p = GDSFPolicy()
        p.add(k(0), 1000, now=0.0, hits=1)  # large, one hit
        p.add(k(1), 10, now=0.0, hits=1)  # small, one hit
        assert p.pop_victim(1.0) == k(0)

    def test_gdsf_frequency_raises_priority(self):
        p = GDSFPolicy()
        p.add(k(0), 100, now=0.0)
        p.add(k(1), 100, now=0.0)
        for _ in range(5):
            p.touch(k(1), now=1.0)
        assert p.pop_victim(2.0) == k(0)

    def test_gdsf_aging_clock_lets_new_objects_compete(self):
        p = GDSFPolicy()
        p.add(k(0), 1, now=0.0)
        for _ in range(50):
            p.touch(k(0), now=0.0)
        assert p.pop_victim(0.0) is not None  # clock jumps to victim prio
        p.add(k(1), 1, now=1.0)  # enters at the aged clock, not at zero
        p.add(k(2), 1, now=1.0)
        assert p.pop_victim(1.0) in (k(1), k(2))

    def test_membership_and_remove(self):
        for p in (LRUPolicy(), LFUPolicy(), TTLPolicy(5.0), GDSFPolicy()):
            p.add(k(0), 1, now=0.0)
            assert k(0) in p and len(p) == 1
            assert p.remove(k(0)) and not p.remove(k(0))
            assert k(0) not in p and len(p) == 0
            assert p.pop_victim(1.0) is None

    def test_make_policy_specs(self):
        assert isinstance(make_policy("lru"), LRUPolicy)
        assert isinstance(make_policy("lfu"), LFUPolicy)
        assert isinstance(make_policy("gdsf"), GDSFPolicy)
        ttl = make_policy("ttl/2.5")
        assert isinstance(ttl, TTLPolicy) and ttl.ttl_s == 2.5
        with pytest.raises(ValueError):
            make_policy("arc")


# ---------------------------------------------------------------------------
# Radix eviction: the leak fix + policy plumbing
# ---------------------------------------------------------------------------
def _chain_tokens(n_chunks: int, g: int = 4, seed: int = 0) -> list[int]:
    rng = random.Random(seed)
    return [rng.randrange(1000) for _ in range(n_chunks * g)]


class TestRadixEviction:
    def test_on_evict_surfaces_every_evicted_key(self):
        evicted = []
        idx = RadixIndex(4, max_chunks=2, on_evict=evicted.append)
        keys = chunk_keys(_chain_tokens(4), 4)
        idx.insert_keys(keys[:1])
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=1), 4))
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=2), 4))
        assert len(idx) == 2
        assert idx.evictions == 1 and len(evicted) == 1
        assert evicted[0] not in idx._nodes

    def test_evicted_objects_deleted_from_store_exactly_once(self):
        store = InMemoryStore()
        deletes = []

        def on_evict(key):
            deletes.append(key)
            store.delete(key)

        idx = RadixIndex(4, max_chunks=3, on_evict=on_evict)
        for seed in range(8):
            keys = chunk_keys(_chain_tokens(2, seed=seed), 4)
            for key in idx.insert_keys(keys):
                if idx.contains(key):  # not self-evicted within the burst
                    store.put(key, b"x")
        # coherence: the store holds exactly the indexed keys, and every
        # delete was for a distinct key (no double delete)
        assert len(deletes) == len(set(deletes))
        assert store.stats.deletes == len(deletes)
        assert {key for key in idx._nodes} == set(store._data)
        assert len(idx) <= 3

    def test_pinned_leaves_are_never_evicted(self):
        idx = RadixIndex(4, max_chunks=1)
        pinned = chunk_keys(_chain_tokens(1, seed=0), 4)
        idx.insert_keys(pinned)
        idx.pin(pinned)
        other = chunk_keys(_chain_tokens(1, seed=1), 4)
        idx.insert_keys(other)
        # over budget but the only other resident is pinned: the new leaf is
        # the sole evictable node and gets evicted
        assert idx.contains(pinned[0])
        assert len(idx) == 1

    def test_unpin_restores_evictability(self):
        idx = RadixIndex(4, max_chunks=1)
        keys = chunk_keys(_chain_tokens(1, seed=0), 4)
        idx.insert_keys(keys)
        idx.pin(keys)
        idx.unpin(keys)
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=1), 4))
        assert len(idx) == 1
        assert not idx.contains(keys[0])  # LRU: the older unpinned leaf went

    def test_internal_nodes_evict_only_once_leaf(self):
        evicted = []
        idx = RadixIndex(4, max_chunks=2, on_evict=evicted.append)
        keys = chunk_keys(_chain_tokens(3, seed=0), 4)
        idx.insert_keys(keys)  # chain of 3: two internal + leaf
        # only the tail leaf was evictable; evicting it frees its parent
        # into the evictable set, but the budget already holds
        assert len(idx) == 2
        assert evicted == [keys[2]]
        assert idx.contains(keys[0]) and idx.contains(keys[1])
        assert idx.stats()["evictable"] == 1

    def test_eviction_cascades_up_freed_parents(self):
        evicted = []
        idx = RadixIndex(4, max_chunks=1, on_evict=evicted.append)
        idx.insert_keys(chunk_keys(_chain_tokens(4, seed=0), 4))
        # budget 1: the whole spine above the leaf unwinds leaf-first
        assert len(idx) == 1
        assert len(evicted) == 3

    def test_match_refreshes_recency(self):
        idx = RadixIndex(4, max_chunks=2)
        a = chunk_keys(_chain_tokens(1, seed=0), 4)
        b = chunk_keys(_chain_tokens(1, seed=1), 4)
        idx.insert_keys(a)
        idx.insert_keys(b)
        idx.match_keys(a)  # a becomes most recent
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=2), 4))
        assert idx.contains(a[0]) and not idx.contains(b[0])

    def test_peek_match_does_not_refresh(self):
        idx = RadixIndex(4, max_chunks=2)
        a = chunk_keys(_chain_tokens(1, seed=0), 4)
        b = chunk_keys(_chain_tokens(1, seed=1), 4)
        idx.insert_keys(a)
        idx.insert_keys(b)
        idx.match_keys(a, touch=False)  # scoring peek: no recency update
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=2), 4))
        assert not idx.contains(a[0]) and idx.contains(b[0])

    def test_ttl_sweep_fires_on_evict(self):
        t = [0.0]
        evicted = []
        idx = RadixIndex(4, clock=lambda: t[0], policy=TTLPolicy(10.0),
                         on_evict=evicted.append)
        keys = chunk_keys(_chain_tokens(1, seed=0), 4)
        idx.insert_keys(keys)
        t[0] = 5.0
        assert idx.sweep_expired() == []
        t[0] = 11.0
        assert idx.sweep_expired() == keys
        assert evicted == keys and len(idx) == 0

    def test_gdsf_size_aware_eviction(self):
        idx = RadixIndex(4, max_chunks=2, policy=GDSFPolicy(),
                         chunk_bytes=1000)
        hot = chunk_keys(_chain_tokens(1, seed=0), 4)
        idx.insert_keys(hot)
        for _ in range(5):
            idx.match_keys(hot)
        cold = chunk_keys(_chain_tokens(1, seed=1), 4)
        idx.insert_keys(cold)
        idx.insert_keys(chunk_keys(_chain_tokens(1, seed=2), 4))
        assert idx.contains(hot[0]) and not idx.contains(cold[0])


class TestRadixStoreCoherenceConcurrent:
    def test_concurrent_match_insert_pin_with_eviction(self):
        """The tentpole coherence contract under concurrency: pinned nodes
        survive, and every evicted key is deleted from the backing store
        exactly once — the final store contents equal the index contents."""
        store = InMemoryStore()
        delete_counts: dict[bytes, int] = {}
        lock = threading.Lock()

        def on_evict(key):
            with lock:
                delete_counts[key] = delete_counts.get(key, 0) + 1
            store.delete(key)

        idx = RadixIndex(4, max_chunks=16, on_evict=on_evict)
        pinned = chunk_keys(_chain_tokens(4, seed=999), 4)
        idx.insert_keys(pinned)
        for key in pinned:
            store.put(key, b"p")
        idx.pin(pinned)
        errors = []

        def writer(wid):
            try:
                for i in range(40):
                    keys = chunk_keys(
                        _chain_tokens(2, seed=wid * 1000 + i), 4)
                    for key in idx.insert_keys(keys):
                        if idx.contains(key):
                            store.put(key, b"x")
                    idx.match_keys(keys)
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        def pinner():
            try:
                for _ in range(100):
                    idx.pin(pinned)
                    idx.match_keys(pinned)
                    idx.unpin(pinned)
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

        threads = [threading.Thread(target=writer, args=(w,))
                   for w in range(4)] + [threading.Thread(target=pinner)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors
        # pinned chain survived every eviction storm
        for key in pinned:
            assert idx.contains(key)
        # no key was deleted twice
        assert all(c == 1 for c in delete_counts.values()), delete_counts
        # store == index (coherence), and the budget held
        assert set(store._data) == set(idx._nodes)
        assert len(idx) <= 16


# ---------------------------------------------------------------------------
# TieredStore hot tier under pluggable policies
# ---------------------------------------------------------------------------
class TestTieredStorePolicies:
    def _tiered(self, capacity=4, policy=None):
        t = [0.0]
        ts = TieredStore(InMemoryStore(), hot_capacity_bytes=capacity,
                         hot_policy=policy, clock=lambda: t[0])
        return ts, t

    def test_hot_occupancy_never_exceeds_capacity(self):
        ts, _ = self._tiered(capacity=4)
        for i in range(8):
            ts.put(k(i), b"ab")
        snap = ts.tier_snapshot()
        assert snap["hot"]["resident_bytes"] <= 4
        assert snap["hot"]["evictions"] == 6

    def test_promotion_interacts_with_policy(self):
        """A get from cold promotes into the hot tier and must evict per the
        policy — LRU: the least-recently-touched resident goes."""
        ts, t = self._tiered(capacity=4)
        ts.put(k(0), b"ab")
        t[0] = 1.0
        ts.put(k(1), b"cd")
        t[0] = 2.0
        ts.get(k(0))  # refresh k0
        t[0] = 3.0
        ts.put(k(4), b"ef")  # must evict k1 (LRU), not k0
        hot = ts._hot
        assert k(0) in hot and k(4) in hot and k(1) not in hot

    def test_lfu_hot_tier_keeps_frequent_object(self):
        ts, t = self._tiered(capacity=4, policy=LFUPolicy())
        ts.put(k(0), b"ab")
        ts.put(k(1), b"cd")
        for i in range(3):
            t[0] = float(i)
            ts.get(k(1))
        ts.put(k(2), b"ef")  # LFU evicts k0 even though k1 is older
        assert k(1) in ts._hot and k(0) not in ts._hot

    def test_delete_removes_from_policy_and_counts(self):
        ts, _ = self._tiered(capacity=4)
        ts.put(k(0), b"ab")
        ts.delete(k(0))
        assert k(0) not in ts._hot
        assert ts.stats.deletes == 1
        ts.put(k(1), b"cd")
        ts.put(k(2), b"ef")  # fits: the deleted resident freed its bytes
        assert ts.tier_snapshot()["hot"]["resident_bytes"] <= 4

    def test_cold_demotion_still_readable(self):
        ts, _ = self._tiered(capacity=2)
        ts.put(k(0), b"ab")
        ts.put(k(1), b"cd")  # evicts k0 from hot
        assert ts.get(k(0)) == b"ab"  # cold tier serves it


# ---------------------------------------------------------------------------
# Serving-layer coherence: orchestrator deletes evicted objects
# ---------------------------------------------------------------------------
class TestOrchestratorEvictionCoherence:
    def test_index_eviction_deletes_gateway_objects(self):
        store = InMemoryStore()
        gw = Gateway(store)
        spec = KVSpec(num_layers=2, chunk_tokens=4, num_kv_heads=1,
                      head_dim=8, dtype_bytes=2)
        idx = RadixIndex(4, max_chunks=4)
        orch = Orchestrator(idx, gw, spec)
        assert idx.on_evict is not None  # installed by the orchestrator
        for seed in range(6):
            tokens = _chain_tokens(2, seed=seed)
            keys = chunk_keys(tokens, 4)
            orch.commit(tokens, {key: b"obj" for key in keys})
        # every object in the store is still indexed: eviction deleted the rest
        assert set(store._data) == set(idx._nodes)
        assert orch.stats["evicted_objects"] == store.stats.deletes
        assert orch.stats["evicted_objects"] > 0
        assert len(idx) <= 4


# ---------------------------------------------------------------------------
# Workload generators
# ---------------------------------------------------------------------------
class TestWorkloads:
    def test_deterministic_and_seed_sensitive(self):
        a = zipf_system_prompt_trace(50, 10.0, seed=3)
        b = zipf_system_prompt_trace(50, 10.0, seed=3)
        c = zipf_system_prompt_trace(50, 10.0, seed=4)
        assert a == b
        assert a != c

    def test_zipf_skew_concentrates_popularity(self):
        trace = zipf_system_prompt_trace(2000, 10.0, seed=0,
                                         num_tenants=1,
                                         prompts_per_tenant=16,
                                         prompt_alpha=1.2)
        counts: dict[str, int] = {}
        for tr in trace:
            counts[tr.prefix_id] = counts.get(tr.prefix_id, 0) + 1
        top = max(counts.values())
        assert top / len(trace) > 2.0 / 16  # far above the uniform share

    def test_rag_prefixes_are_cross_tenant(self):
        trace = rag_trace(500, 10.0, seed=1, num_docs=8, doc_alpha=1.0)
        tenants_per_doc: dict[str, set] = {}
        for tr in trace:
            tenants_per_doc.setdefault(tr.prefix_id, set()).add(tr.tenant)
        assert max(len(ts) for ts in tenants_per_doc.values()) > 1

    def test_churn_rotates_working_set(self):
        trace = tenant_churn_trace(600, 20.0, cohort=4, cohort_life_s=5.0,
                                   overlap=0, seed=0)
        early = {tr.tenant for tr in trace if tr.arrival_s < 4.0}
        late = {tr.tenant for tr in trace if tr.arrival_s > 25.0}
        assert early and late and not (early & late)

    def test_trace_v2_roundtrip(self, tmp_path):
        trace = zipf_system_prompt_trace(20, 10.0, seed=5)
        path = str(tmp_path / "t.json")
        save_trace(path, trace)
        loaded = load_trace(path)
        assert loaded == sorted(trace,
                                key=lambda r: (r.arrival_s, r.req_id))

    def test_v1_trace_still_loads(self):
        trace = load_trace(os.path.join(DATA, "golden_trace.json"))
        assert trace and all(tr.tenant == "" and tr.hot_tokens == 0
                             for tr in trace)

    def test_working_set_chunks(self):
        trace = [TraceRequest("a", 0.0, 256, 0.5, 64, prefix_id="p"),
                 TraceRequest("b", 1.0, 256, 0.5, 64, prefix_id="p"),
                 TraceRequest("c", 2.0, 256, 0.5, 64, prefix_id="q")]
        assert working_set_chunks(trace) == 4  # 2 prefixes x 2 chunks


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
class _StubNode:
    def __init__(self, inflight=0, cache=None):
        self.inflight = inflight
        self.cache = cache


class _StubCache:
    def __init__(self, score):
        self._score = score

    def peek_chunks(self, chain):
        return self._score


def _req(prefix="p0"):
    return TraceRequest("r0", 0.0, 256, 0.5, 64, prefix_id=prefix)


class TestRouting:
    def test_random_is_seed_deterministic(self):
        nodes = [_StubNode() for _ in range(4)]
        a = [RandomRouter(seed=1).route(_req(), nodes, []) for _ in range(1)]
        b = [RandomRouter(seed=1).route(_req(), nodes, []) for _ in range(1)]
        assert a == b

    def test_round_robin_cycles(self):
        r = RoundRobinRouter()
        nodes = [_StubNode() for _ in range(3)]
        assert [r.route(_req(), nodes, []) for _ in range(6)] == [
            0, 1, 2, 0, 1, 2]

    def test_consistent_hash_is_prefix_stable(self):
        r = ConsistentHashRouter()
        nodes = [_StubNode() for _ in range(5)]
        picks = {r.route(_req("doc7"), nodes, []) for _ in range(10)}
        assert len(picks) == 1
        assert r.route(_req("doc8"), nodes, []) in range(5)

    def test_consistent_hash_remaps_minimally(self):
        r = ConsistentHashRouter(virtual=128)
        five = [_StubNode() for _ in range(5)]
        six = [_StubNode() for _ in range(6)]
        moved = 0
        n = 200
        for i in range(n):
            a = r.route(_req(f"doc{i}"), five, [])
            b = r.route(_req(f"doc{i}"), six, [])
            moved += a != b
        assert moved / n < 0.45  # naive mod-N rehash moves ~5/6

    def test_affinity_prefers_warmest_node(self):
        nodes = [_StubNode(cache=_StubCache(0)),
                 _StubNode(cache=_StubCache(5)),
                 _StubNode(cache=_StubCache(2))]
        assert AffinityRouter().route(_req(), nodes, []) == 1

    def test_affinity_sheds_under_imbalance(self):
        r = AffinityRouter(max_imbalance=4)
        nodes = [_StubNode(inflight=6, cache=_StubCache(5)),
                 _StubNode(inflight=1, cache=_StubCache(0))]
        assert r.route(_req(), nodes, []) == 1
        assert r.shed == 1

    def test_affinity_ties_break_to_least_loaded(self):
        nodes = [_StubNode(inflight=3, cache=_StubCache(0)),
                 _StubNode(inflight=1, cache=_StubCache(0))]
        assert AffinityRouter().route(_req(), nodes, []) == 1

    def test_make_router(self):
        for spec, cls in (("random", RandomRouter),
                          ("round_robin", RoundRobinRouter),
                          ("hash", ConsistentHashRouter),
                          ("affinity", AffinityRouter)):
            assert isinstance(make_router(spec), cls)
        with pytest.raises(ValueError):
            make_router("sticky")


# ---------------------------------------------------------------------------
# Chain derivation
# ---------------------------------------------------------------------------
class TestChains:
    def test_shared_prefix_same_keys_unique_suffix(self):
        a = TraceRequest("a", 0.0, 512, 0.5, 64, prefix_id="p")
        b = TraceRequest("b", 1.0, 512, 0.5, 64, prefix_id="p")
        ca, cb = request_chain(a), request_chain(b)
        assert len(ca) == len(cb) == 8
        assert ca[:4] == cb[:4]  # shared prefix dedups
        assert not set(ca[4:]) & set(cb[4:])  # suffixes are disjoint

    def test_prefix_memoisation(self):
        memo = {}
        a = request_chain(TraceRequest("a", 0.0, 512, 0.5, 64,
                                       prefix_id="p"), memo)
        b = request_chain(TraceRequest("b", 0.0, 512, 0.5, 64,
                                       prefix_id="p"), memo)
        assert a[:4] == b[:4] and ("p", 4) in memo

    def test_no_prefix_id_means_private_chain(self):
        a = request_chain(TraceRequest("a", 0.0, 512, 0.5, 64))
        b = request_chain(TraceRequest("b", 0.0, 512, 0.5, 64))
        assert not set(a) & set(b)

    def test_derive_chain_is_deterministic(self):
        assert derive_chain(GENESIS, "x", 5) == derive_chain(GENESIS, "x", 5)
        assert derive_chain(GENESIS, "x", 5) != derive_chain(GENESIS, "y", 5)


# ---------------------------------------------------------------------------
# Fleet simulator
# ---------------------------------------------------------------------------
class TestFleetConformance:
    def test_single_node_random_matches_cluster_sim(self):
        trace = poisson_trace(60, rate_rps=6.0, seed=11)
        ref = ClusterSim(cap_bps=40 * GBPS, max_flows=8).run(trace)
        res = FleetSim(1, make_router("random"), cap_bps=40 * GBPS,
                       max_flows=8).run(trace)
        ra, rb = ref.by_id(), res.by_id()
        assert set(ra) == set(rb)
        for rid in ra:
            for field in ("admit_s", "flow_done_s", "prefill_done_s",
                          "bytes_total"):
                assert getattr(rb[rid], field) == pytest.approx(
                    getattr(ra[rid], field), abs=1e-9), (rid, field)
        assert all(r.node == 0 for r in res.records)

    def test_single_node_closed_loop_matches(self):
        trace_args = dict(clients=6, think_s=0.05, requests_per_client=4,
                          seed=2)
        ref = ClusterSim(cap_bps=40 * GBPS).run(ClosedLoopTrace(**trace_args))
        res = FleetSim(1, make_router("random"),
                       cap_bps=40 * GBPS).run(ClosedLoopTrace(**trace_args))
        ra, rb = ref.by_id(), res.by_id()
        assert set(ra) == set(rb)
        for rid in ra:
            assert rb[rid].ttft_s == pytest.approx(ra[rid].ttft_s, abs=1e-9)

    def test_epoch_mode_rejected(self):
        with pytest.raises(ValueError):
            FleetSim(2, make_router("random"), epoch_s=0.1)

    def test_chunk_tokens_mismatch_rejected(self):
        sim = FleetSim(1, make_router("random"),
                       cache=CacheConfig(hot_capacity_bytes=1 << 30,
                                         chunk_tokens=64))
        bad = [TraceRequest("r0", 0.0, 4096, 0.5, chunk_tokens=32)]
        with pytest.raises(ValueError):
            sim.run(bad)


def _small_fleet(nodes=2, router="affinity", capacity=None, policy="lru",
                 **kw):
    cap = capacity if capacity is not None else 4 * 1024 ** 3
    return FleetSim(nodes, make_router(router, seed=7),
                    cache=CacheConfig(hot_capacity_bytes=cap, policy=policy),
                    cap_bps=20 * GBPS, max_flows=8, **kw)


def _small_trace(n=80, seed=1):
    return zipf_system_prompt_trace(n, rate_rps=40.0, seed=seed,
                                    num_tenants=6, prompts_per_tenant=3,
                                    prompt_tokens=2048, context=4096)


class TestFleetCacheMode:
    def test_hit_rates_warm_up_over_time(self):
        res = _small_fleet().run(_small_trace())
        first = [r for r in res.records[:10]]
        last = [r for r in res.records[-30:]]
        assert sum(r.hit_rate for r in last) / 30 \
            > sum(r.hit_rate for r in first) / 10
        # the very first arrival finds a cold namespace
        assert res.records[0].hit_rate == 0.0

    def test_hot_tokens_bounded_by_cached_tokens(self):
        res = _small_fleet().run(_small_trace())
        for r in res.records:
            assert 0 <= r.hot_tokens <= r.cached_tokens

    def test_occupancy_within_capacity(self):
        cap = 256 * 1024 ** 2  # tight: forces sustained eviction
        res = _small_fleet(capacity=cap).run(_small_trace(n=120))
        for st in res.node_stats:
            c = st["cache"]
            assert c["resident_bytes"] <= cap
            assert c["peak_bytes"] <= cap
            assert c["index"]["evictions"] > 0

    def test_store_index_coherence_after_run(self):
        sim = _small_fleet(capacity=256 * 1024 ** 2)
        sim.run(_small_trace(n=120))
        for node in sim.nodes:
            cache = node.cache
            assert set(cache.store._sizes) == set(cache.index._nodes)

    def test_affinity_beats_random_under_zipf(self):
        trace = _small_trace(n=100)
        aff = _small_fleet(router="affinity").run(trace).metrics()
        rnd = _small_fleet(router="random").run(trace).metrics()
        assert aff.hot_token_rate > rnd.hot_token_rate
        assert aff.egress_bytes < rnd.egress_bytes

    def test_records_carry_node_and_tenant(self):
        res = _small_fleet().run(_small_trace())
        assert {r.node for r in res.records} <= {0, 1}
        assert all(r.tenant.startswith("t") for r in res.records)

    def test_per_tenant_rollup(self):
        res = _small_fleet().run(_small_trace())
        byt = res.per_tenant()
        assert set(byt) == {r.tenant for r in res.records}
        assert sum(m.n for m in byt.values()) == len(res.records)

    def test_node_stats_rollup(self):
        res = _small_fleet().run(_small_trace())
        m = res.metrics()
        assert sum(st["egress_bytes"] for st in res.node_stats) \
            == pytest.approx(m.egress_bytes, abs=1e-6)
        assert sum(st["hot_tokens"] for st in res.node_stats) == m.hot_tokens
        assert res.global_chunks > 0 and res.global_bytes > 0

    def test_ledger_store_is_control_plane_only(self):
        s = ByteLedgerStore()
        s.put(k(0), b"abc")
        s.put(k(0), b"abc")
        assert s.stats.puts == 1 and s.stats.dedup_hits == 1
        assert s.total_bytes() == 3 and s.contains(k(0))
        with pytest.raises(TypeError):
            s.get(k(0))
        s.delete(k(0))
        assert s.stats.deletes == 1 and len(s) == 0

    def test_injectable_real_store(self):
        cfg = CacheConfig(hot_capacity_bytes=1 << 30,
                          store_factory=InMemoryStore)
        sim = FleetSim(1, make_router("random"), cache=cfg,
                       cap_bps=20 * GBPS)
        res = sim.run(_small_trace(n=20))
        node = sim.nodes[0]
        assert set(node.cache.store._data) == set(node.cache.index._nodes)
        assert res.metrics().n == 20


# ---------------------------------------------------------------------------
# Metrics regressions
# ---------------------------------------------------------------------------
class TestMetricsRegressions:
    def test_goodput_nan_for_single_request(self):
        rec = RequestRecord("r0", 4096, 0.5, arrival_s=0.0)
        rec.prefill_done_s = 0.0  # zero-makespan degenerate case
        m = summarize([rec])
        assert math.isnan(m.goodput_rps)  # was inf: poisoned ratios silently

    def test_goodput_defined_for_two_requests(self):
        recs = []
        for i in range(2):
            r = RequestRecord(f"r{i}", 4096, 0.5, arrival_s=float(i))
            r.prefill_done_s = float(i) + 1.0
            recs.append(r)
        assert summarize(recs).goodput_rps == pytest.approx(1.0)

    def test_per_tenant_partitions_records(self):
        recs = []
        for i, tenant in enumerate(["a", "a", "b"]):
            r = RequestRecord(f"r{i}", 4096, 0.5, arrival_s=0.0,
                              tenant=tenant)
            r.prefill_done_s = 1.0
            recs.append(r)
        byt = per_tenant(recs)
        assert byt["a"].n == 2 and byt["b"].n == 1


# ---------------------------------------------------------------------------
# Golden fleet trace (committed fixture, bit-identical replay)
# ---------------------------------------------------------------------------
class TestGoldenFleetTrace:
    def _run(self):
        trace = load_trace(os.path.join(DATA, "golden_trace_fleet.json"))
        sim = FleetSim(2, make_router("affinity"),
                       cache=CacheConfig(hot_capacity_bytes=2 * 1024 ** 3,
                                         policy="lru"),
                       cap_bps=20 * GBPS, max_flows=8)
        return sim.run(trace)

    def test_replay_matches_committed_table(self):
        with open(os.path.join(DATA,
                               "golden_trace_fleet_expected.json")) as f:
            expected = json.load(f)
        res = self._run()
        got = res.by_id()
        assert len(got) == len(expected["requests"])
        for rowx in expected["requests"]:
            r = got[rowx["req_id"]]
            assert r.node == rowx["node"], rowx["req_id"]
            assert r.hot_tokens == rowx["hot_tokens"], rowx["req_id"]
            assert r.hit_rate == pytest.approx(rowx["hit_rate"], abs=1e-12)
            assert r.ttft_s == pytest.approx(rowx["ttft_s"], abs=1e-9)
        assert res.global_chunks == expected["global_chunks"]
        assert res.shed == expected["shed"]

    def test_same_trace_is_bit_identical(self):
        a, b = self._run(), self._run()
        ra = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in a.records]
        rb = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in b.records]
        assert ra == rb  # exact equality, not approx
        assert a.global_chunks == b.global_chunks
