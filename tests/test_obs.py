"""Observability layer (DESIGN.md §Observability): tracer and metric units,
Chrome trace-event export + schema validation, TTFT-waterfall rendering,
added-TTFT attribution — including the exact identity on the committed golden
cluster and fleet traces — and the zero-perturbation contract (attaching a
tracer changes no simulated timestamp)."""
import json
import math
import os
import subprocess
import sys
import threading

import pytest

from repro.cluster import ClusterSim, TraceRequest, load_trace, summarize
from repro.cluster.metrics import percentile
from repro.core.scheduler import Policy
from repro.core.simulator import PAPER_MARGIN_BPS
from repro.fleet import make_router
from repro.fleet.sim import CacheConfig, FleetSim
from repro.obs import (MetricsRegistry, Span, Tracer,
                       assert_valid_chrome_trace, attribute_flow,
                       attribute_trace, check_identity, format_attribution,
                       render_waterfall, to_chrome_trace,
                       validate_chrome_trace, write_chrome_trace)

DATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "data")
GBPS = 1e9 / 8


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------
class TestTracer:
    def test_explicit_timestamps_never_read_the_clock(self):
        boom = type("Boom", (), {"now": staticmethod(
            lambda: (_ for _ in ()).throw(AssertionError("clock read")))})()
        tr = Tracer(boom)
        tr.span_at("t", "a", 1.0, 2.0)
        tr.instant("t", "b", t=1.5)
        assert len(tr) == 2

    def test_injected_clock_stamps_clock_scoped_emission(self):
        clk = FakeClock(10.0)
        tr = Tracer(clk)
        with tr.span("t", "work") as args:
            clk.t = 12.5
            args["n"] = 3
        (s,) = tr.spans("t")
        assert (s.t0, s.t1, s.args["n"]) == (10.0, 12.5, 3)
        assert tr.instants("t") == []
        tr.instant("t", "evt")
        assert tr.instants("t")[0].t == 12.5

    def test_seq_preserves_emission_order_at_equal_times(self):
        tr = Tracer(FakeClock())
        a = tr.instant("t", "a", t=1.0)
        b = tr.instant("t", "b", t=1.0)
        assert a.seq < b.seq

    def test_span_tree_nests_by_containment_not_emission_order(self):
        tr = Tracer(FakeClock())
        # children emitted before the parent, interleaved with another track
        tr.span_at("r1", "inner", 2.0, 3.0)
        tr.span_at("r2", "other", 0.0, 9.0)
        tr.span_at("r1", "mid", 1.0, 4.0)
        tr.span_at("r1", "outer", 0.0, 5.0)
        (root,) = tr.span_tree("r1")
        assert root.span.name == "outer"
        (mid,) = root.children
        assert mid.span.name == "mid"
        assert [s.name for _, s in root.walk()] == ["outer", "mid", "inner"]
        depths = dict((s.name, d) for d, s in root.walk())
        assert depths == {"outer": 0, "mid": 1, "inner": 2}

    def test_identical_intervals_nest_first_recorded_as_parent(self):
        tr = Tracer(FakeClock())
        tr.span_at("t", "first", 0.0, 1.0)
        tr.span_at("t", "second", 0.0, 1.0)
        (root,) = tr.span_tree("t")
        assert root.span.name == "first"
        assert root.children[0].span.name == "second"

    def test_tracks_queries_and_clear(self):
        tr = Tracer(FakeClock())
        tr.span_at("a", "x", 0.0, 1.0)
        tr.instant("b", "y", t=0.5)
        tr.span_at("a", "z", 1.0, 2.0)
        assert tr.tracks() == ["a", "b"]
        assert [s.name for s in tr.spans("a")] == ["x", "z"]
        assert [s.name for s in tr.spans(name="z")] == ["z"]
        assert [i.name for i in tr.instants()] == ["y"]
        tr.clear()
        assert len(tr) == 0 and tr.tracks() == []


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_counter_gauge_roundtrip(self):
        reg = MetricsRegistry()
        c = reg.counter("c")
        c.inc()
        c.inc(4)
        assert c.value == 5
        assert reg.counter("c") is c  # same name -> same instrument
        g = reg.gauge("g")
        g.set(2.5)
        assert g.value == 2.5

    def test_histogram_nearest_rank_matches_cluster_metrics(self):
        reg = MetricsRegistry()
        h = reg.histogram("h")
        xs = [float(i) for i in range(37)]
        for x in xs:
            h.observe(x)
        snap = h.snapshot()
        assert snap["count"] == 37 and snap["min"] == 0.0 and snap["max"] == 36.0
        for q in (0.50, 0.95, 0.99):
            assert snap[f"p{int(q * 100)}"] == percentile(xs, q)

    def test_empty_histogram_snapshots_nan_not_raise(self):
        snap = MetricsRegistry().histogram("h").snapshot()
        assert snap["count"] == 0
        assert math.isnan(snap["mean"]) and math.isnan(snap["p99"])

    def test_group_dict_and_attribute_access_share_storage(self):
        reg = MetricsRegistry()
        st = reg.group("orch", ("hits", "misses"))
        st["hits"] += 1
        st.hits += 2
        assert st["hits"] == st.hits == 3
        assert "hits" in st and "nope" not in st
        assert sorted(st.keys()) == ["hits", "misses"]
        assert st.snapshot() == {"hits": 3, "misses": 0}
        with pytest.raises(AttributeError):
            st.nope

    def test_registry_snapshot_is_one_cut_of_everything(self):
        reg = MetricsRegistry()
        reg.counter("n.a").inc(2)
        reg.gauge("n.g").set(1.0)
        reg.histogram("n.h").observe(3.0)
        snap = reg.snapshot()
        assert snap["counters"] == {"n.a": 2}
        assert snap["gauges"] == {"n.g": 1.0}
        assert snap["histograms"]["n.h"]["count"] == 1

    def test_concurrent_paired_adds_never_tear(self):
        """The StatGroup invariant the engine relies on: two fields updated
        by one `add` are observed together by every concurrent snapshot."""
        reg = MetricsRegistry()
        st = reg.group("engine", ("prefix_tokens_reused", "tokens_computed"))
        PROMPT, N = 64, 300
        torn, stop = [], threading.Event()

        def reader():
            while not stop.is_set():
                s = st.snapshot()
                if (s["prefix_tokens_reused"] + s["tokens_computed"]) % PROMPT:
                    torn.append(s)

        readers = [threading.Thread(target=reader) for _ in range(2)]
        for t in readers:
            t.start()

        def writer(seed):
            for i in range(N):
                reused = (seed * 31 + i) % PROMPT
                st.add(prefix_tokens_reused=reused,
                       tokens_computed=PROMPT - reused)

        writers = [threading.Thread(target=writer, args=(s,)) for s in range(4)]
        for w in writers:
            w.start()
        for w in writers:
            w.join()
        stop.set()
        for t in readers:
            t.join()
        assert not torn
        s = st.snapshot()
        assert s["prefix_tokens_reused"] + s["tokens_computed"] == 4 * N * PROMPT


# ---------------------------------------------------------------------------
# Chrome trace export + schema validation + waterfall
# ---------------------------------------------------------------------------
class TestExport:
    def _tracer(self):
        tr = Tracer(FakeClock())
        tr.span_at("n0/r0", "serve", 0.0, 2.0, cat="cluster", layer=0)
        tr.span_at("n0/r0", "wire", 0.5, 1.0, cat="wire")
        tr.instant("n1/pool", "realloc", t=0.25, cat="pool", flows=2)
        tr.span_at("bare", "x", 0.0, 1.0)
        return tr

    def test_export_structure_and_track_split(self):
        doc = to_chrome_trace(self._tracer())
        assert_valid_chrome_trace(doc)
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        procs = {e["args"]["name"] for e in meta if e["name"] == "process_name"}
        threads = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
        assert procs == {"n0", "n1", "repro"}  # "bare" lands in the default
        assert threads == {"r0", "pool", "bare"}
        xs = [e for e in evs if e["ph"] == "X"]
        assert [e["name"] for e in xs] == ["serve", "x", "wire"]  # (ts, seq)
        serve = xs[0]
        assert serve["ts"] == 0.0 and serve["dur"] == 2.0e6  # µs
        (inst,) = [e for e in evs if e["ph"] == "i"]
        assert inst["s"] == "t" and inst["args"]["flows"] == 2
        # spans on different processes get different pids
        assert serve["pid"] != inst["pid"]

    def test_export_is_deterministic_and_json_roundtrips(self, tmp_path):
        p = tmp_path / "trace.json"
        doc = write_chrome_trace(self._tracer(), str(p))
        with open(p) as f:
            loaded = json.load(f)
        assert loaded == doc
        assert validate_chrome_trace(loaded) == []
        assert json.dumps(doc) == json.dumps(to_chrome_trace(self._tracer()))

    def test_validator_catches_malformed_docs(self):
        assert validate_chrome_trace([]) != []
        assert validate_chrome_trace({}) != []
        bad = {"traceEvents": [
            {"name": "a", "ph": "Z", "ts": 0, "pid": 1, "tid": 1},
            {"name": "b", "ph": "X", "ts": 0, "dur": -1, "pid": 1, "tid": 1},
            {"name": "c", "ph": "X", "ts": -5, "dur": 1, "pid": 1, "tid": 1},
            {"name": "d", "ph": "i", "ts": 0, "s": "q", "pid": 1, "tid": 1},
            {"ph": "X", "ts": 0, "dur": 1, "pid": 1, "tid": 1},
            {"name": "f", "ph": "X", "ts": 0, "dur": 1, "pid": "x", "tid": 1},
        ]}
        errors = validate_chrome_trace(bad)
        assert len(errors) == 6
        with pytest.raises(ValueError):
            assert_valid_chrome_trace(bad)

    def test_validate_cli(self, tmp_path):
        good = tmp_path / "good.json"
        write_chrome_trace(self._tracer(), str(good))
        bad = tmp_path / "bad.json"
        bad.write_text('{"traceEvents": [{"ph": "Z"}]}')
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(DATA), os.pardir, "src")
        env["PYTHONPATH"] = os.path.abspath(src)
        runs = {str(good): 0, str(bad): 1}
        for path, want in runs.items():
            proc = subprocess.run(
                [sys.executable, "-m", "repro.obs.export", "--validate", path],
                env=env, capture_output=True, text=True)
            assert proc.returncode == want, proc.stdout + proc.stderr
        proc = subprocess.run(
            [sys.executable, "-m", "repro.obs.export"], env=env,
            capture_output=True, text=True)
        assert proc.returncode == 2

    def test_waterfall_renders_nested_rows(self):
        tr = self._tracer()
        out = render_waterfall(tr, "n0/r0")
        lines = out.splitlines()
        assert "track n0/r0" in lines[0]
        assert any(l.lstrip().startswith("serve") for l in lines)
        assert any(l.lstrip().startswith("wire") for l in lines)
        # nested span is indented deeper than its parent
        serve_line = next(l for l in lines if l.lstrip().startswith("serve"))
        wire_line = next(l for l in lines if l.lstrip().startswith("wire"))
        assert (len(wire_line) - len(wire_line.lstrip())
                > len(serve_line) - len(serve_line.lstrip()))
        assert render_waterfall(tr, "nope").startswith("(no spans")


# ---------------------------------------------------------------------------
# Attribution unit behaviour
# ---------------------------------------------------------------------------
class TestAttributionUnits:
    def test_recompute_mode_attributes_everything_to_queue(self):
        a = attribute_flow("r", "recompute", arrival_s=0.0, admit_s=0.3,
                           prefill_done_s=1.3, num_layers=10,
                           layer_compute_s=0.1, per_layer_bytes=[0.0] * 10,
                           n_objects=0)
        assert a.queue_s == pytest.approx(0.3)
        assert a.bandwidth_stall_s == 0.0 and a.gate_stall_s == 0.0
        assert a.added_ttft_s == pytest.approx(0.3)
        assert abs(a.residual_s) < 1e-12

    def test_layerwise_requires_avail_rel(self):
        with pytest.raises(ValueError):
            attribute_flow("r", "layerwise", arrival_s=0.0, admit_s=0.0,
                           prefill_done_s=1.0, num_layers=2,
                           layer_compute_s=0.1, per_layer_bytes=[1.0, 1.0],
                           n_objects=1)

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError):
            attribute_flow("r", "warp", arrival_s=0.0, admit_s=0.0,
                           prefill_done_s=1.0, num_layers=2,
                           layer_compute_s=0.1, per_layer_bytes=[1.0],
                           n_objects=1)

    def test_check_identity_raises_on_fudged_components(self):
        import dataclasses
        a = attribute_flow("r", "recompute", arrival_s=0.0, admit_s=0.3,
                           prefill_done_s=1.3, num_layers=10,
                           layer_compute_s=0.1, per_layer_bytes=[0.0] * 10,
                           n_objects=0)
        broken = dataclasses.replace(a, queue_s=a.queue_s + 1e-3)
        with pytest.raises(AssertionError):
            check_identity({"r": broken})
        assert check_identity({"r": a}) <= 1e-12

    def test_format_attribution_is_a_table(self):
        a = attribute_flow("req-1", "recompute", arrival_s=0.0, admit_s=0.0,
                           prefill_done_s=1.0, num_layers=4,
                           layer_compute_s=0.25, per_layer_bytes=[0.0] * 4,
                           n_objects=0)
        out = format_attribution({"req-1": a})
        assert "req-1" in out and "recompute" in out
        assert len(out.splitlines()) == 3  # header, rule, one row


# ---------------------------------------------------------------------------
# Golden traces: zero perturbation + exact attribution identity
# ---------------------------------------------------------------------------
def _run_golden_cluster(tracer=None):
    trace = load_trace(os.path.join(DATA, "golden_trace.json"))
    sim = ClusterSim(cap_bps=50 * GBPS, policy=Policy.CAL_STALL_OPT,
                     margin_bps=PAPER_MARGIN_BPS, tracer=tracer)
    return sim.run(trace)


def _run_golden_fleet(tracer=None):
    trace = load_trace(os.path.join(DATA, "golden_trace_fleet.json"))
    sim = FleetSim(2, make_router("affinity"),
                   cache=CacheConfig(hot_capacity_bytes=2 * 1024 ** 3,
                                     policy="lru"),
                   cap_bps=20 * GBPS, max_flows=8, tracer=tracer)
    return sim.run(trace)


def _record_key(r):
    return (r.req_id, r.arrival_s, r.admit_s, r.flow_done_s,
            r.prefill_done_s, r.bytes_total, r.layer_compute_s, r.replanned)


class TestGoldenClusterObservability:
    def test_tracer_changes_no_simulated_timestamp(self):
        bare = _run_golden_cluster()
        traced = _run_golden_cluster(Tracer())
        assert ([_record_key(r) for r in bare.records]
                == [_record_key(r) for r in traced.records])  # exact, not approx
        assert bare.events == traced.events
        assert bare.reallocs == traced.reallocs

    def test_attribution_identity_within_1e6(self):
        tr = Tracer()
        res = _run_golden_cluster(tr)
        attrs = attribute_trace(tr)
        done = [r for r in res.records if r.done]
        assert len(attrs) == len(done) > 0
        assert check_identity(attrs, tol=1e-6) < 1e-6
        by_id = {r.req_id: r for r in done}
        for rid, a in attrs.items():
            assert a.ttft_s == pytest.approx(by_id[rid].ttft_s, abs=1e-12)
            assert a.queue_s == pytest.approx(by_id[rid].queue_s, abs=1e-12)

    def test_every_request_has_spans_and_summary(self):
        tr = Tracer()
        res = _run_golden_cluster(tr)
        for r in res.records:
            if not r.done:
                continue
            names = {s.name for s in tr.spans(r.req_id)}
            assert "serve" in names
            assert tr.instants(r.req_id, "arrive")
            assert len(tr.instants(r.req_id, "request")) == 1
        assert tr.instants("pool", "realloc")  # pool track is live

    def test_export_round_trips_the_schema(self, tmp_path):
        tr = Tracer()
        _run_golden_cluster(tr)
        p = tmp_path / "golden.json"
        write_chrome_trace(tr, str(p))
        with open(p) as f:
            assert validate_chrome_trace(json.load(f)) == []


class TestGoldenFleetObservability:
    def test_tracer_changes_no_simulated_timestamp(self):
        bare = _run_golden_fleet()
        traced = _run_golden_fleet(Tracer())
        ka = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in bare.records]
        kb = [(r.req_id, r.node, r.hot_tokens, r.hit_rate, r.ttft_s,
               r.bytes_total) for r in traced.records]
        assert ka == kb
        assert bare.global_chunks == traced.global_chunks

    def test_attribution_identity_within_1e6(self):
        tr = Tracer()
        res = _run_golden_fleet(tr)
        attrs = attribute_trace(tr)
        done = [r for r in res.records if r.done]
        assert len(attrs) == len(done) > 0
        assert check_identity(attrs, tol=1e-6) < 1e-6

    def test_per_node_tracks_and_route_instants(self):
        tr = Tracer()
        res = _run_golden_fleet(tr)
        tracks = set(tr.tracks())
        prefixes = {t.split("/", 1)[0] for t in tracks if "/" in t}
        assert {"n0", "n1"} <= prefixes or {"n0"} <= prefixes
        routes = tr.instants("fleet/router", "route")
        assert len(routes) == len(res.records)
        assert {i.args["node"] for i in routes} \
            <= {0, 1}
        # each request's spans live on its owning node's track
        for r in res.records:
            if r.done:
                assert tr.spans(f"n{r.node}/{r.req_id}", "serve")


# ---------------------------------------------------------------------------
# cluster.metrics edge cases (documented in its module docstring)
# ---------------------------------------------------------------------------
class TestClusterMetricsEdges:
    def test_summarize_empty_yields_nan_percentiles_zero_makespan(self):
        m = summarize([])
        assert m.n == 0 and m.makespan_s == 0.0
        for v in (m.ttft_p50_s, m.ttft_p95_s, m.ttft_p99_s, m.ttft_mean_s,
                  m.goodput_rps):
            assert math.isnan(v)
        assert m.total_ttft_s == 0.0 and m.queue_total_s == 0.0

    def test_percentile_empty_is_nan(self):
        assert math.isnan(percentile([], 0.5))

    def test_nearest_rank_p99_equals_max_below_100_samples(self):
        """Nearest-rank: the ceil(0.99 n)-th order statistic IS the max for
        every n < 100 — tail percentiles need >= 100 samples to separate
        from the max (documented in `cluster.metrics`)."""
        for n in (1, 5, 50, 99):
            xs = [float(i) for i in range(n)]
            assert percentile(xs, 0.99) == max(xs)
        xs = [float(i) for i in range(100)]
        assert percentile(xs, 0.99) == 98.0  # first n where p99 < max

    def test_zero_makespan_goodput_is_nan_not_inf(self):
        from repro.cluster.metrics import RequestRecord
        rec = RequestRecord("r0", 4096, 0.5, arrival_s=1.0, admit_s=1.0,
                            flow_done_s=1.0, prefill_done_s=1.0)
        m = summarize([rec])
        assert m.n == 1 and m.makespan_s == 0.0
        assert math.isnan(m.goodput_rps)
        assert m.ttft_p50_s == 0.0  # percentiles stay defined


# ---------------------------------------------------------------------------
# Replanner history records as trace instants
# ---------------------------------------------------------------------------
class TestReplanTracing:
    def test_replans_emit_instants_matching_history(self):
        from repro.core.compute_model import PaperComputeModel
        from repro.core.simulator import ServingSimulator
        from repro.core.transport import S3_RDMA_AGG
        from repro.hybrid.policy import HybridReplanner
        compute = PaperComputeModel()
        spec = ServingSimulator().kv_spec(64)
        rep = HybridReplanner(compute=compute, profile=S3_RDMA_AGG, spec=spec)
        tr = Tracer()
        sim = ClusterSim(cap_bps=2 * GBPS, replanner=rep, tracer=tr)
        sim.run([TraceRequest("r0", 1.0, 16384, 0.875)])
        insts = tr.instants("pool", "replan")
        assert len(insts) == len(rep.history) == 1
        rec = rep.history[0]
        assert insts[0].t == rec.t_s == 1.0
        assert insts[0].args["req_id"] == rec.req_id == "r0"
        assert insts[0].args["fetch_chunks"] == rec.fetch_chunks
        assert insts[0].args["offered_rate"] == rec.offered_rate
