"""The runnable examples must actually run (subprocess, CPU)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(name: str, timeout: int = 540) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_quickstart():
    out = _run_example("quickstart.py")
    assert "OK: cached-prefix logits == from-scratch logits" in out


def test_multi_tenant_scheduling():
    out = _run_example("multi_tenant_scheduling.py")
    assert "Workload A" in out and "cal-stall-opt" in out
    # spot-check one Table A9 cell (A / stall-opt / 64K,87.5% = 24.81 Gbps)
    assert "24.81G" in out


def test_layerwise_overlap():
    out = _run_example("layerwise_overlap.py")
    assert "B_req" in out


def test_cluster_trace():
    out = _run_example("cluster_trace.py")
    assert "OK: JSON replay reproduces bit-identical metrics" in out
    assert "cal-stall-opt" in out


def test_trace_waterfall():
    out = _run_example("trace_waterfall.py")
    assert "OK: attribution telescopes exactly" in out
    assert "OK: exported" in out and "Chrome trace events" in out
    assert "OK: tracer attached changed no simulated timestamp" in out
    # the waterfall itself rendered, with nested wire/compute rows
    assert "track r" in out and "wire" in out and "compute" in out


def test_hybrid_prefill():
    out = _run_example("hybrid_prefill.py")
    assert "OK: hybrid <= min(pure-fetch, pure-recompute)" in out
    assert "OK: hybrid-prefill logits == no-cache logits" in out


@pytest.mark.slow
def test_train_ft():
    out = _run_example("train_ft.py")
    assert "OK: training survived failure and converged" in out


def test_bench_run_only_unknown_name_fails_fast():
    """`benchmarks/run.py --only <typo>` must exit non-zero before running
    anything, and name the known benchmarks in the message."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "no_such_bench"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=120)
    assert out.returncode != 0
    assert "unknown benchmark 'no_such_bench'" in out.stderr
    assert "bench_codec" in out.stderr  # the fix-it list is printed
    assert "name,us_per_call" not in out.stdout  # nothing ran

def test_bench_run_list():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=120)
    assert out.returncode == 0
    assert "bench_codec" in out.stdout and "bench_cluster" in out.stdout
