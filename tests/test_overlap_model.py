"""Tests of the layerwise overlap model (Eq. 3) and the TTFT simulator's
agreement with the paper's headline claims (§5.5–5.7, Table A8/A12)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Policy, chunkwise_ttft, layerwise_ttft,
                        per_layer_stalls, pipeline_ttft, required_bandwidth)
from repro.core.compute_model import A100_LLAMA31_8B, PaperComputeModel
from repro.core.simulator import (PAPER_MARGIN_BPS, WORKLOAD_A, WORKLOAD_B,
                                  WORKLOAD_C, ServingSimulator,
                                  WorkloadRequest)


class TestEq3:
    def test_transfer_bound(self):
        # X >> C: every stage exposes transfer; TTFT = sum X + C_last
        X, C = [2.0] * 4, [1.0] * 4
        assert layerwise_ttft(X, C) == pytest.approx(2 + 2 * 3 + 1)

    def test_compute_bound(self):
        # X << C: only X_0 is visible
        X, C = [0.5] * 4, [2.0] * 4
        assert layerwise_ttft(X, C) == pytest.approx(0.5 + 3 * 2 + 2)

    def test_chunkwise_upper_bounds_layerwise(self):
        X, C = [1.0, 2.0, 0.5, 1.5], [1.0, 1.0, 2.0, 0.5]
        assert layerwise_ttft(X, C) <= chunkwise_ttft(sum(X), C)

    @given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=64),
           st.lists(st.floats(0.0, 10.0), min_size=1, max_size=64))
    @settings(max_examples=100, deadline=None)
    def test_property_eq3_bounds_event_stepping(self, X, C):
        """Eq. 3 models ONE-layer prefetch (transfer l+1 starts only after
        stage l), so it upper-bounds the unconstrained pipeline where layer l
        arrives at cumsum(X)[l], and both are at most chunkwise."""
        n = min(len(X), len(C))
        X, C = X[:n], C[:n]
        ready = []
        t = 0.0
        for x in X:
            t += x
            ready.append(t)
        eq3 = layerwise_ttft(X, C)
        assert pipeline_ttft(ready, C) <= eq3 + 1e-9
        assert eq3 <= chunkwise_ttft(sum(X), C) + 1e-9

    @given(st.floats(0.01, 10.0), st.floats(0.01, 10.0), st.integers(1, 64))
    @settings(max_examples=100, deadline=None)
    def test_property_eq3_exact_for_constant_layers(self, x, c, L):
        """In the paper's regime (footnote 1: s_i, c_i constant across layers)
        Eq. 3 and event-stepping agree exactly."""
        X, C = [x] * L, [c] * L
        ready = [x * (l + 1) for l in range(L)]
        assert layerwise_ttft(X, C) == pytest.approx(pipeline_ttft(ready, C))

    @given(st.lists(st.floats(0.01, 10.0), min_size=2, max_size=32),
           st.lists(st.floats(0.01, 10.0), min_size=2, max_size=32))
    @settings(max_examples=100, deadline=None)
    def test_property_layerwise_never_worse(self, X, C):
        n = min(len(X), len(C))
        assert layerwise_ttft(X[:n], C[:n]) <= chunkwise_ttft(sum(X[:n]), C[:n]) + 1e-9

    def test_stalls_localised(self):
        ready = [1.0, 1.5, 10.0]
        C = [1.0, 1.0, 1.0]
        stalls = per_layer_stalls(ready, C)
        assert stalls == pytest.approx([1.0, 0.0, 7.0])


class TestTableA8:
    """The compute model must reproduce Table A8's required-bandwidth column."""

    @pytest.mark.parametrize("key,expect_gbs", [
        ((4096, 0.500), 1.45), ((4096, 0.875), 7.41),
        ((16384, 0.500), 1.12), ((16384, 0.875), 6.67),
        ((32768, 0.500), 0.83), ((32768, 0.875), 4.92),
        ((65536, 0.500), 0.50), ((65536, 0.875), 3.10),
    ])
    def test_required_bw(self, key, expect_gbs):
        m = PaperComputeModel()
        got = m.required_bw(*key) / 1e9
        assert got == pytest.approx(expect_gbs, rel=0.02)

    def test_longer_context_relaxes_bandwidth(self):
        """§5.4's counter-intuitive takeaway: more cached bytes, but a larger
        compute window — B_req falls with context at fixed hit rate."""
        m = PaperComputeModel()
        for r in (0.5, 0.875):
            bws = [m.required_bw(c, r) for c in (4096, 16384, 32768, 65536)]
            assert bws == sorted(bws, reverse=True)


class TestHeadlineTTFT:
    def test_64k_overhead_within_paper_band(self):
        """S3Agg-LW within 0.1–5.6% of opt-local-LW at 64K (G=64)."""
        sim = ServingSimulator()
        for r in (0.5, 0.875):
            w = WorkloadRequest("w", 65536, r, 64)
            lw = sim.ttft_layerwise(w).ttft_s
            opt = sim.ttft_opt_local(w)
            overhead = lw / opt - 1
            assert 0.0 <= overhead <= 0.056, (r, overhead)

    def test_4k_overhead_tens_of_ms(self):
        """At 4K the gap is fixed-cost dominated: 56–75 ms band (G=64)."""
        sim = ServingSimulator()
        for r in (0.5, 0.875):
            w = WorkloadRequest("w", 4096, r, 64)
            gap = sim.ttft_layerwise(w).ttft_s - sim.ttft_opt_local(w)
            assert 0.040 <= gap <= 0.085, (r, gap)

    def test_g16_worse_than_g64_at_64k(self):
        """§5.5: small chunk granularity prevents full aggregation throughput."""
        sim = ServingSimulator()
        t16 = sim.ttft_layerwise(WorkloadRequest("w", 65536, 0.875, 16)).ttft_s
        t64 = sim.ttft_layerwise(WorkloadRequest("w", 65536, 0.875, 64)).ttft_s
        assert t16 > t64

    def test_layerwise_less_sensitive_to_bandwidth(self):
        """§5.6: a 10 Gbps cap barely moves 64K/50% (B_req=0.5 GB/s) but
        hits 87.5% hit-rate configs (B_req > cap)."""
        sim = ServingSimulator()
        cap = 10e9 / 8
        w_lo = WorkloadRequest("lo", 65536, 0.5, 64)
        w_hi = WorkloadRequest("hi", 65536, 0.875, 64)
        lo_incr = (sim.ttft_layerwise(w_lo, rate_limit=cap).ttft_s /
                   sim.ttft_layerwise(w_lo).ttft_s) - 1
        hi_incr = (sim.ttft_layerwise(w_hi, rate_limit=cap).ttft_s /
                   sim.ttft_layerwise(w_hi).ttft_s) - 1
        assert lo_incr < 0.02
        assert hi_incr > 0.25

    def test_scheduler_beats_equal_on_paper_workloads(self):
        """Fig. 16 / Table A12: Calibrated Stall-opt reduces added TTFT vs
        Equal by 1.2–1.8x on workloads A and B."""
        sim = ServingSimulator()
        for reqs, cap in (WORKLOAD_A, WORKLOAD_B):
            base = sim.unthrottled_total_ttft(reqs)
            added_eq = sim.workload_total_ttft(reqs, cap, Policy.EQUAL) - base
            added_cal = sim.workload_total_ttft(
                reqs, cap, Policy.CAL_STALL_OPT, PAPER_MARGIN_BPS) - base
            assert added_cal < added_eq
            assert added_eq / max(added_cal, 1e-9) > 1.15

    def test_workload_c_stall_opt_close_to_calibrated(self):
        """§5.7: under the dense 50 Gbps Workload C the margin can mildly
        over-provision — plain Stall-opt is competitive; both beat Equal."""
        sim = ServingSimulator()
        reqs, cap = WORKLOAD_C
        base = sim.unthrottled_total_ttft(reqs)
        added = {p: sim.workload_total_ttft(
            reqs, cap, p, PAPER_MARGIN_BPS if p is Policy.CAL_STALL_OPT else 0.0)
            - base for p in (Policy.EQUAL, Policy.STALL_OPT, Policy.CAL_STALL_OPT)}
        assert added[Policy.STALL_OPT] < added[Policy.EQUAL]
        assert added[Policy.CAL_STALL_OPT] < added[Policy.EQUAL]
