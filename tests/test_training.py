"""Training substrate tests: optimizer, data determinism, checkpointing,
fault-tolerant supervisor, and an end-to-end loss-goes-down run."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import (AdamWConfig, SimulatedFailure, SyntheticLM,
                            TrainSupervisor, adamw_init, adamw_update,
                            latest_step, make_train_step, restore_checkpoint,
                            save_checkpoint)
from repro.training.optimizer import lr_schedule


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw of w^2
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert np.abs(np.asarray(params["w"])).max() < 0.05

    def test_clip_bounds_update(self):
        cfg = AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = adamw_init(params, cfg)
        _, _, metrics = adamw_update({"w": jnp.full(4, 1e6)}, state, params, cfg)
        assert metrics["grad_norm"] > 1e6  # raw norm reported

    def test_bf16_moments(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        params = {"w": jnp.zeros((8, 8))}
        state = adamw_init(params, cfg)
        assert state["m"]["w"].dtype == jnp.bfloat16

    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(lr_schedule(cfg, s)) for s in range(0, 100, 5)]
        assert lrs[0] < lrs[1]  # warmup
        assert lrs[-1] < cfg.lr  # decayed
        assert min(lrs[2:]) >= cfg.lr * cfg.lr_min_ratio * 0.99


class TestData:
    def test_deterministic_and_restart_safe(self):
        d = SyntheticLM(1000, 32, 8, seed=3)
        a, b = d.batch_at(7), d.batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLM(1000, 16, 8, seed=1, num_hosts=1).batch_at(0)
        parts = [SyntheticLM(1000, 16, 8, seed=1, host_id=h, num_hosts=2
                             ).batch_at(0) for h in range(2)]
        assert all(p["tokens"].shape == (4, 16) for p in parts)
        # different hosts draw different streams
        assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])

    def test_labels_shift(self):
        d = SyntheticLM(1000, 16, 2, seed=0)
        b = d.batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_iterator(self):
        d = SyntheticLM(1000, 8, 2, seed=0)
        it = d.iterate(5)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], d.batch_at(5)["tokens"])
        d.close()


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                "b": {"c": jnp.float32(3.5)}}
        save_checkpoint(str(tmp_path), 3, tree)
        assert latest_step(str(tmp_path)) == 3
        got, _ = restore_checkpoint(str(tmp_path), 3, tree)
        assert got["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert float(got["b"]["c"]) == 3.5

    def test_atomic_commit_no_partial(self, tmp_path):
        tree = {"w": jnp.zeros((4,))}
        save_checkpoint(str(tmp_path), 1, tree)
        files = os.listdir(tmp_path)
        assert files == ["step_00000001"]  # no .tmp residue

    def test_async_save(self, tmp_path):
        t = save_checkpoint(str(tmp_path), 2, {"w": jnp.ones(3)},
                            async_save=True)
        t.join()
        assert latest_step(str(tmp_path)) == 2


def _tiny_setup(tmp_path=None, steps=300, lr=1e-2):
    cfg = get_smoke_config("smollm-135m")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                       weight_decay=0.01)
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_train_step(model, ocfg, remat=False))
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    return model, params, opt, step, data


class TestEndToEnd:
    def test_loss_decreases(self):
        model, params, opt, step, data = _tiny_setup()
        losses = []
        for s in range(100):
            params, opt, m = step(params, opt, data.batch_at(s))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.4, losses[::10]

    def test_microbatching_matches_full_batch_loss(self):
        cfg = get_smoke_config("smollm-135m")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0)
        opt = adamw_init(params, ocfg)
        data = SyntheticLM(cfg.vocab_size, 16, 8, seed=0).batch_at(0)
        s1 = make_train_step(model, ocfg, remat=False, microbatches=1)
        s4 = make_train_step(model, ocfg, remat=False, microbatches=4)
        p1, _, m1 = s1(params, opt, data)
        p4, _, m4 = s4(params, opt, data)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-3, atol=1e-4)


class TestSupervisor:
    def test_failure_restart_resumes(self, tmp_path):
        model, params, opt, step, data = _tiny_setup()
        sup = TrainSupervisor(step, params, opt, ckpt_dir=str(tmp_path),
                              ckpt_every=5)
        fired = {"done": False}

        def inject(s):
            if s == 12 and not fired["done"]:
                fired["done"] = True
                raise SimulatedFailure("node lost")

        stats = sup.run(data.batch_at, 20, failure_injector=inject)
        assert stats.restarts == 1
        # resumed from step 10 ckpt -> replayed steps 10..19 plus 0..11
        assert stats.steps_done == 20 + 2

    def test_nan_rollback(self, tmp_path):
        model, params, opt, step, data = _tiny_setup()
        calls = {"n": 0}

        def poisoned_step(p, o, b):
            calls["n"] += 1
            if calls["n"] == 7:
                p2, o2, m = step(p, o, b)
                return p2, o2, {**m, "loss": jnp.float32(np.nan)}
            return step(p, o, b)

        sup = TrainSupervisor(poisoned_step, params, opt,
                              ckpt_dir=str(tmp_path), ckpt_every=3)
        stats = sup.run(data.batch_at, 10)
        assert stats.rollbacks == 1
        assert all(np.isfinite(l) for l in stats.losses)

    def test_checkpoints_pruned(self, tmp_path):
        model, params, opt, step, data = _tiny_setup()
        sup = TrainSupervisor(step, params, opt, ckpt_dir=str(tmp_path),
                              ckpt_every=2, keep=2)
        sup.run(data.batch_at, 8)
        dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
        assert len(dirs) <= 2
