"""Trace-driven cluster simulation (DESIGN.md §Cluster-sim).

Generates a seeded Poisson arrival trace over the paper's §5.7 request mix,
replays it through the discrete-event cluster simulator under EQUAL and
Calibrated Stall-opt, and prints TTFT percentiles + total added TTFT for
each.  Also demonstrates the committed-JSON replay format: the trace is
saved, reloaded, and re-run — metrics must be bit-identical (the
determinism contract regression tests rely on).

Run:  PYTHONPATH=src python examples/cluster_trace.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import (ClusterSim, load_trace, poisson_trace, save_trace,
                           summarize)
from repro.core.scheduler import Policy
from repro.core.simulator import PAPER_MARGIN_BPS, ServingSimulator, WorkloadRequest

GBPS = 1e9 / 8
CAP = 80 * GBPS

trace = poisson_trace(24, rate_rps=1.0, seed=0)
sim0 = ServingSimulator()
baseline = {t.req_id: sim0.ttft_layerwise(
    WorkloadRequest(t.req_id, t.context, t.hit_rate)).ttft_s for t in trace}

print(f"Poisson trace: {len(trace)} requests over "
      f"{trace[-1].arrival_s:.1f}s, cap 80 Gbps\n")
print(f"{'policy':16s} {'p50':>8s} {'p95':>8s} {'p99':>8s} "
      f"{'added TTFT':>11s} {'reallocs':>8s}")
results = {}
for pol, margin in ((Policy.EQUAL, 0.0),
                    (Policy.CAL_STALL_OPT, PAPER_MARGIN_BPS)):
    res = ClusterSim(cap_bps=CAP, policy=pol, margin_bps=margin).run(trace)
    m = summarize(res.records, baseline)
    results[pol] = m
    print(f"{pol.value:16s} {m.ttft_p50_s*1e3:7.0f}m {m.ttft_p95_s*1e3:7.0f}m "
          f"{m.ttft_p99_s*1e3:7.0f}m {m.added_ttft_total_s*1e3:10.0f}m "
          f"{res.reallocs:8d}")
ratio = (results[Policy.EQUAL].added_ttft_total_s
         / results[Policy.CAL_STALL_OPT].added_ttft_total_s)
print(f"\ncal-stall-opt reduces added TTFT {ratio:.2f}x vs equal "
      f"(paper static window: 1.2-1.8x)")

# --- replay round-trip: save -> load -> identical metrics -------------------
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "trace.json")
    save_trace(path, trace)
    replayed = load_trace(path)
    m2 = summarize(ClusterSim(cap_bps=CAP, policy=Policy.CAL_STALL_OPT,
                              margin_bps=PAPER_MARGIN_BPS).run(replayed).records,
                   baseline)
assert m2 == results[Policy.CAL_STALL_OPT]
print("OK: JSON replay reproduces bit-identical metrics")
