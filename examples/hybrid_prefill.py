"""Compute-or-load hybrid prefill demo (DESIGN.md §Compute-or-load; after
Cake, arXiv:2410.03065).

Part 1 — paper-scale planner: sweeps the shared-bandwidth cap for one grid
request and prints pure-fetch / pure-recompute / hybrid TTFT with the chosen
split, showing the crossover: fetch-everything at high bandwidth,
recompute-everything near zero, hybrid on the lower envelope in between.

Part 2 — real engine: a bandwidth-capped smollm-135m smoke engine serves the
same prompt twice; the warm request is split by the planner (some chunks
fetched through the object store, the rest recomputed with the suffix) and
its logits must equal the cold no-cache prefill bit for bit.

Run:  PYTHONPATH=src python examples/hybrid_prefill.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.simulator import WorkloadRequest
from repro.hybrid import crossover_sweep

GBPS = 1e9 / 8

w = WorkloadRequest("16K,87.5%", 16384, 0.875, 64)
print(f"Compute-or-load sweep for ctx={w.context} hit={w.hit_rate} "
      f"({w.cached_tokens // w.chunk_tokens} matched chunks):")
print(f"{'rate':>8s} {'pure-fetch':>12s} {'recompute':>12s} {'hybrid':>12s} "
      f"{'split m/n':>10s}")
for r in crossover_sweep(w, [g * GBPS for g in
                             (0.25, 0.5, 1, 2, 4, 8, 16, 32, 100)]):
    assert r["hybrid_s"] <= min(r["fetch_s"], r["recompute_s"]) + 1e-9
    print(f"{r['rate']/GBPS:6.2f}G {r['fetch_s']*1e3:10.1f}ms "
          f"{r['recompute_s']*1e3:10.1f}ms {r['hybrid_s']*1e3:10.1f}ms "
          f"{r['fetch_chunks']:5d}/{r['total_chunks']}")
print("OK: hybrid <= min(pure-fetch, pure-recompute) at every rate\n")

# --------------------------------------------------------------------------
# Part 2: the real JAX path.
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import Gateway, InMemoryStore, MeasuredCompute, RadixIndex
from repro.core.transport import LOCAL_DRAM
from repro.hybrid import HybridPlanner
from repro.models import build_model
from repro.serving import Orchestrator, ServingEngine

G = 8
cfg = get_smoke_config("smollm-135m")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
spec = cfg.kv_spec(G, dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize)
compute = MeasuredCompute(num_layers=spec.num_layers, base_s=0.0,
                          per_token_s=1e-4,
                          bytes_per_token_per_layer=spec.bytes_per_token_per_layer)
orch = Orchestrator(RadixIndex(G), Gateway(InMemoryStore()), spec,
                    theta_bytes=0, bandwidth_cap=1.28e6,
                    hybrid=HybridPlanner(compute, LOCAL_DRAM,
                                         session_setup=False))
engine = ServingEngine(model, params, orch)
prompt = np.random.default_rng(0).integers(0, 200, size=48)
cold = engine.submit(prompt, "cold")
warm = engine.submit(prompt, "warm")
print(f"warm request: delivery={warm.delivery.value}, "
      f"{warm.matched_tokens} tokens fetched + "
      f"{len(prompt) - warm.matched_tokens} recomputed")
assert np.array_equal(cold.logits, warm.logits)
print("OK: hybrid-prefill logits == no-cache logits (bit-for-bit)")
