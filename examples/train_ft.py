"""Fault-tolerant training end-to-end driver.

Trains a ~1M-param SmolLM-family model on the synthetic LM stream for a few
hundred steps with the full production loop: async sharded checkpoints, a
SIMULATED NODE FAILURE at step 60 (restart from the last checkpoint), and a
NaN injection at step 90 (rollback).  Loss must keep descending through both.

Run:  PYTHONPATH=src python examples/train_ft.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import (AdamWConfig, SimulatedFailure, SyntheticLM,
                            TrainSupervisor, adamw_init, make_train_step)

STEPS = 200

cfg = get_smoke_config("smollm-135m")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print(f"model={cfg.name} params={n/1e6:.2f}M")

opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=20, total_steps=STEPS)
opt = adamw_init(params, opt_cfg)
step_fn = jax.jit(make_train_step(model, opt_cfg, remat=False))
data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)

fired = set()

def chaos(step):
    if step == 60 and 60 not in fired:
        fired.add(60)
        print(">>> injecting node failure at step 60")
        raise SimulatedFailure("rack power loss")

with tempfile.TemporaryDirectory() as ckpt:
    sup = TrainSupervisor(step_fn, params, opt, ckpt_dir=ckpt, ckpt_every=25)
    stats = sup.run(data.batch_at, STEPS, failure_injector=chaos)

l = stats.losses
print(f"steps={stats.steps_done} restarts={stats.restarts} "
      f"rollbacks={stats.rollbacks}")
print("loss:", " ".join(f"{x:.2f}" for x in l[::20]))
assert stats.restarts == 1
assert np.mean(l[-10:]) < np.mean(l[:10]) - 0.3
print("OK: training survived failure and converged "
      f"({np.mean(l[:10]):.2f} -> {np.mean(l[-10:]):.2f})")
