"""Quickstart: serve an LLM with ObjectCache prefix reuse in ~40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py

What happens: two requests share a 64-token system prompt.  The first request
computes everything and commits its KV chunks (rolling-hash keys) to the
object store; the second matches the prefix in the radix index, fetches it
back via server-side LAYERWISE aggregation (Table A3 of the paper), and only
computes the 32-token suffix — the logits are identical either way.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import Gateway, InMemoryStore, RadixIndex
from repro.models import build_model
from repro.serving import Orchestrator, ServingEngine

CHUNK_TOKENS = 16  # G — fine granularity preserves branch points (Fig. 3)

cfg = get_smoke_config("llama3-1-8b")  # the paper's model family, CPU-sized
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

spec = cfg.kv_spec(CHUNK_TOKENS,
                   dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize)
orch = Orchestrator(index=RadixIndex(CHUNK_TOKENS),
                    gateway=Gateway(InMemoryStore()),
                    spec=spec, theta_bytes=0)  # theta=0 -> always layerwise
engine = ServingEngine(model, params, orch)

rng = np.random.default_rng(0)
system_prompt = rng.integers(0, cfg.vocab_size, size=64)
req_a = np.concatenate([system_prompt, rng.integers(0, cfg.vocab_size, 32)])
req_b = np.concatenate([system_prompt, rng.integers(0, cfg.vocab_size, 32)])

ra = engine.submit(req_a, "A", max_new_tokens=8)
rb = engine.submit(req_b, "B", max_new_tokens=8)

print(f"A: hit={ra.matched_tokens:3d} tokens  mode={ra.delivery}  "
      f"generated={ra.new_tokens}")
print(f"B: hit={rb.matched_tokens:3d} tokens  mode={rb.delivery.value}  "
      f"generated={rb.new_tokens}")
assert rb.matched_tokens == 64, "B must reuse the shared system prompt"

# correctness: a fresh engine that never saw A produces identical logits
fresh = ServingEngine(model, params,
                      Orchestrator(RadixIndex(CHUNK_TOKENS),
                                   Gateway(InMemoryStore()), spec))
rf = fresh.submit(req_b, "B-fresh")
np.testing.assert_allclose(rb.logits, rf.logits, rtol=1e-4, atol=1e-4)
print("OK: cached-prefix logits == from-scratch logits")
print("store:", orch.gateway.store.stats.snapshot())
