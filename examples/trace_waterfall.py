"""Per-request span timelines + added-TTFT attribution (DESIGN.md
§Observability).

Replays a small Poisson trace through the discrete-event cluster simulator
with a `Tracer` attached, then:

  1. renders the TTFT waterfall (queue / wire / stall / compute spans, nested
     by containment) for the slowest request,
  2. decomposes every request's added TTFT into queue + bandwidth-stall +
     gate-stall + dequant components and checks the telescoping identity
     ``sum(components) == ttft - baseline`` to 1e-6,
  3. exports the full timeline as Perfetto-loadable Chrome trace JSON
     (chrome://tracing or https://ui.perfetto.dev) and validates the schema,
  4. re-runs the identical trace *without* the tracer and asserts bit-equal
     records — attaching observability never moves a simulated timestamp.

Run:  PYTHONPATH=src python examples/trace_waterfall.py
"""
import dataclasses
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import ClusterSim, poisson_trace
from repro.core.scheduler import Policy
from repro.core.simulator import PAPER_MARGIN_BPS
from repro.obs import (Tracer, attribute_trace, check_identity,
                       format_attribution, render_waterfall,
                       validate_chrome_trace, write_chrome_trace)

GBPS = 1e9 / 8
trace = poisson_trace(12, rate_rps=1.5, seed=3)


def run(tracer=None):
    sim = ClusterSim(cap_bps=50 * GBPS, policy=Policy.CAL_STALL_OPT,
                     margin_bps=PAPER_MARGIN_BPS, tracer=tracer)
    return sim.run(trace)


tracer = Tracer()
res = run(tracer)

# -- 1. waterfall for the slowest request ------------------------------------
slowest = max((r for r in res.records if r.done), key=lambda r: r.ttft_s)
print(render_waterfall(tracer, slowest.req_id))

# -- 2. added-TTFT attribution, identity-checked -----------------------------
attrs = attribute_trace(tracer)
print()
print(format_attribution(attrs))
residual = check_identity(attrs, tol=1e-6)
print(f"\nOK: attribution telescopes exactly "
      f"(max identity residual {residual:.2e} <= 1e-6)")

# -- 3. Perfetto export ------------------------------------------------------
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "trace.json")
    doc = write_chrome_trace(tracer, path)
    with open(path) as f:
        errors = validate_chrome_trace(json.load(f))
    assert errors == [], errors
    print(f"OK: exported {len(doc['traceEvents'])} Chrome trace events "
          f"(load in chrome://tracing or ui.perfetto.dev)")

# -- 4. zero-perturbation contract -------------------------------------------
bare = run()
assert ([dataclasses.asdict(r) for r in bare.records]
        == [dataclasses.asdict(r) for r in res.records])
print("OK: tracer attached changed no simulated timestamp")
