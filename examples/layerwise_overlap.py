"""Layerwise transfer/compute overlap study (paper §3.5 Eq. 3, Fig. 7/12/13).

Sweeps context length and hit rate for Llama 3.1 8B and shows, per config:
  * the required overlap bandwidth B_req = D^(l)/t^(l)  (Table A8),
  * chunkwise vs layerwise TTFT (Fig. 7 semantics),
  * the counter-intuitive §5.4 effect: LONGER contexts need LESS bandwidth.

Run:  PYTHONPATH=src python examples/layerwise_overlap.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compute_model import PaperComputeModel
from repro.core.simulator import ServingSimulator, WorkloadRequest

sim = ServingSimulator()
m = PaperComputeModel()

print(f"{'ctx':>6s} {'hit':>6s} {'B_req GB/s':>11s} {'chunkwise':>11s} "
      f"{'layerwise':>11s} {'opt-local':>11s} {'LW overhead':>12s}")
for ctx in (4096, 16384, 32768, 65536):
    for hit in (0.5, 0.875):
        w = WorkloadRequest("w", ctx, hit, 64)
        cw = sim.ttft_chunkwise(w).ttft_s
        lw = sim.ttft_layerwise(w).ttft_s
        opt = sim.ttft_opt_local(w)
        print(f"{ctx:6d} {hit:6.3f} {m.required_bw(ctx, hit)/1e9:11.2f} "
              f"{cw*1e3:9.1f}ms {lw*1e3:9.1f}ms {opt*1e3:9.1f}ms "
              f"{100*(lw/opt-1):11.1f}%")

print("\nNote how B_req FALLS as context grows at fixed hit rate (§5.4): "
      "more cached bytes, but a quadratically larger compute window.")
