"""Multi-tenant bandwidth scheduling (paper §3.6 / §5.7, Fig. 16).

Replays the paper's Workloads A, B, C under their bandwidth caps with all
five policies and reports per-request allocations (reproducing Appendix
Table A9 to rounding precision) and total added TTFT vs the unthrottled
baseline.

Run:  PYTHONPATH=src python examples/multi_tenant_scheduling.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.scheduler import Policy, allocate
from repro.core.simulator import (PAPER_MARGIN_BPS, WORKLOAD_A, WORKLOAD_B,
                                  WORKLOAD_C, ServingSimulator)

GBPS = 1e9 / 8
sim = ServingSimulator()

for name, (reqs, cap) in (("A (80 Gbps)", WORKLOAD_A),
                          ("B (50 Gbps)", WORKLOAD_B),
                          ("C (50 Gbps, 6 tenants)", WORKLOAD_C)):
    print(f"\n=== Workload {name} ===")
    flows = [sim.flow_request(w) for w in reqs]
    base = sim.unthrottled_total_ttft(reqs)
    print(f"{'policy':16s} " +
          " ".join(f"{w.req_id:>10s}" for w in reqs) + "   added TTFT")
    for pol in (Policy.EQUAL, Policy.KV_PROP, Policy.BW_PROP,
                Policy.STALL_OPT, Policy.CAL_STALL_OPT):
        margin = PAPER_MARGIN_BPS if pol is Policy.CAL_STALL_OPT else 0.0
        alloc = allocate(flows, cap, pol, margin)
        total = sim.workload_total_ttft(reqs, cap, pol, margin)
        cells = " ".join(f"{alloc[w.req_id]/GBPS:9.2f}G" for w in reqs)
        print(f"{pol.value:16s} {cells}   +{(total-base)*1e3:7.0f} ms")
    print("(compare per-request Gbps with paper Appendix Table A9)")
